"""Train an LM end-to-end with the production stack (Trainer + AdamW +
checkpointing + restart) on the synthetic token pipeline.

Default is a CPU-sized model for a quick demonstration; ``--size 100m``
builds a ~100M-param llama-style config (a few hundred steps is a real run
on accelerators; on this CPU container expect ~1 min/step).  ``--arch``
trains any assigned architecture's smoke config instead.  ``--irc`` enables
the paper's technique: every projection is ternary-quantized via STE (QAT)
so the trained model is crossbar-mappable.

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --arch hymba-1.5b --irc
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data import SyntheticLMData
from repro.models import LM, LMConfig
from repro.models.lm_config import IRCMode
from repro.train import make_train_step
from repro.train.steps import init_train_state
from repro.train.trainer import Trainer, TrainerConfig


def size_config(size: str) -> LMConfig:
    if size == "100m":
        return LMConfig(name="lm-100m", n_layers=12, d_model=768, n_heads=12,
                        n_kv_heads=4, head_dim=64, d_ff=2048,
                        vocab_size=32768, dtype="float32",
                        param_dtype="float32")
    return LMConfig(name="lm-small", n_layers=4, d_model=256, n_heads=4,
                    n_kv_heads=2, head_dim=64, d_ff=688, vocab_size=4096,
                    dtype="float32", param_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--size", default="small", choices=["small", "100m"])
    ap.add_argument("--arch", default=None,
                    help="train an assigned arch's smoke config instead")
    ap.add_argument("--irc", action="store_true",
                    help="ternary-QAT every projection (the paper's mode)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = (get_config(args.arch, "smoke") if args.arch
           else size_config(args.size))
    if args.irc:
        cfg = dataclasses.replace(cfg, irc=IRCMode(enabled=True))
    lm = LM(cfg)
    n_params = sum(int(jnp.size(x)) for x in jax.tree.leaves(
        lm.abstract_params()))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, irc={args.irc}")

    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch)
    state = init_train_state(lm, jax.random.PRNGKey(0))
    step_fn = make_train_step(lm, remat="none",
                              lr_fn=lambda s: jnp.float32(args.lr))
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
                      ckpt_dir=args.ckpt_dir, log_every=max(args.steps // 20, 1)),
        step_fn, lambda s: data.batch_for_step(s), state)
    hist = trainer.run()
    print(f"\nloss: first10={sum(h['loss'] for h in hist[:10])/10:.4f} "
          f"last10={sum(h['loss'] for h in hist[-10:])/10:.4f}")
    if trainer.straggler_steps:
        print(f"straggler steps detected: {trainer.straggler_steps[:10]}")


if __name__ == "__main__":
    main()
