"""End-to-end driver (the paper's task): train the IRC object detector with
QAT on synthetic IVS-geometry data, then evaluate the full structural
crossbar simulation under the paper's nonideal-effect ablation (Table II)
for BOTH designs:

  proposed : ternary 20/60/20, no BN, single-shot, extra bias
  baseline : binary + shared reference, in-memory BN, partial sums

The ablation runs as chip-population Monte Carlo (`run_ablation_detector`):
each column reports POPULATION mean±std mAP@0.5 over `--mc-chips` sampled
dies, and the per-chip metric vectors, the QAT step timing (compile vs
steady-state), and the per-chunk convergence stream land in an
`experiments/<run_id>/` run directory (manifest.json + metrics.jsonl +
per-chip .npy; `--run-dir ''` disables, `--trace` adds a profiler trace).

Defaults are CPU-sized (32x32 images, ~200 steps, a few minutes); pass
--full for the paper's 1024x576 geometry (cluster-scale).

  PYTHONPATH=src python examples/train_detector.py --steps 200
"""
import argparse

import jax

from repro.configs import yolo_irc
from repro.core import NonidealConfig
from repro.data.detection import SyntheticDetectionData
from repro.mc import McConfig, run_ablation_detector
from repro.models import IRCDetector
from repro.obs import NULL_RUNLOG, PhaseTimer, maybe_runlog, timed_step
from repro.optim import AdamWConfig, adamw_init, warmup_step_decay
from repro.train.steps import ensemble_key_for_step, make_det_qat_step

ABLATION = [
    ("ideal", NonidealConfig.none()),
    ("dev-var", NonidealConfig(device_variation=True)),
    ("dev+nl", NonidealConfig(device_variation=True, nonlinearity=True)),
    ("dev+nl+sa", NonidealConfig(device_variation=True, nonlinearity=True,
                                 sa_variation=True, sensing_range=True)),
    ("all", NonidealConfig.all()),
]


def train(det, data, steps, batch, lr, seed=0, noise_cfg=NonidealConfig.none(),
          train_chips=1, resample_every=1, key=None, obs=NULL_RUNLOG,
          design=""):
    """QAT on the shared step builder (`repro.train.steps.make_det_qat_step`).

    `train_chips=1` is the legacy single-draw surrogate; >=2 trains against a
    chip population (ensemble-aware QAT, paper Sec. V at population scale).
    `key` roots BOTH the per-step noise stream and the chip-population
    stream, so a run is reproducible from one key (defaults to the
    historical PRNGKey(1)).  Steps are phase-timed: the first call's
    compile latency is split from the steady-state steps/sec, both logged
    through `obs`.
    """
    params = det.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    timer = PhaseTimer("qat_step", unit="steps")
    step_fn = timed_step(jax.jit(make_det_qat_step(
        det, train_chips=train_chips, cfg_ni=noise_cfg,
        opt_cfg=AdamWConfig(weight_decay=1e-3))), timer)  # paper: AdamW 1e-3
    root = jax.random.PRNGKey(1) if key is None else key

    for s in range(steps):
        b = data.batch_for_step(s, batch)
        lr_s = warmup_step_decay(s, base_lr=lr, warmup_steps=max(steps // 10, 1),
                                 decay_points=((int(steps * 0.7), lr / 10),
                                               (int(steps * 0.9), lr / 100)))
        params, opt, loss = step_fn(params, opt, b.images, b.targets, lr_s,
                                    jax.random.fold_in(root, s),
                                    ensemble_key_for_step(root, s,
                                                          resample_every))
        if s % max(steps // 10, 1) == 0:
            print(f"  step {s:4d}  loss {float(loss):8.4f} "
                  f"({timer.total_s:5.1f}s)", flush=True)
            obs.log_event("train_step", design=design, step=s,
                          loss=float(loss), step_time_s=timer.last_s)
    timer.log_to(obs, design=design, train_chips=train_chips)
    print(f"  qat: compile {timer.compile_s:.1f}s, "
          f"{timer.rate():.2f} steps/s steady", flush=True)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--mc-chips", type=int, default=8,
                    help="chip-population size per ablation column")
    ap.add_argument("--mc-chunk", type=int, default=0,
                    help="MC chunk size (0 = whole population per chunk)")
    ap.add_argument("--stderr-target", type=float, default=None,
                    help="stop each MC column once the mAP standard error "
                         "reaches this target")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale 1024x576 geometry")
    ap.add_argument("--designs", default="proposed,baseline")
    ap.add_argument("--qat-noise", action="store_true",
                    help="variation-aware QAT: surrogate nonideal noise "
                         "during training (paper Sec. V)")
    ap.add_argument("--train-chips", type=int, default=1,
                    help="ensemble-aware QAT: chip realizations per step "
                         "(implies --qat-noise; 1 = legacy single draw)")
    ap.add_argument("--resample-every", type=int, default=1,
                    help="QAT steps between chip-population resamples")
    ap.add_argument("--run-dir", default="experiments",
                    help="root for the experiments/<run_id>/ run directory "
                         "('' disables)")
    ap.add_argument("--run-id", default="")
    ap.add_argument("--trace", action="store_true",
                    help="capture a jax.profiler trace into the run dir")
    args = ap.parse_args()

    obs = maybe_runlog(bool(args.run_dir), "train-detector",
                       args=vars(args), root=args.run_dir,
                       run_id=args.run_id or None)
    if obs.path is not None:
        print(f"# run dir: {obs.path}")
    if args.trace:
        obs.start_trace()

    noise_cfg = (NonidealConfig.all()
                 if (args.qat_noise or args.train_chips > 1)
                 else NonidealConfig.none())
    mc = McConfig(n_chips=args.mc_chips,
                  chunk_size=args.mc_chunk or args.mc_chips)
    results = {}
    for design in args.designs.split(","):
        cfg = (yolo_irc.proposed() if design == "proposed"
               else yolo_irc.baseline()) if args.full else \
            yolo_irc.smoke("ternary" if design == "proposed" else "binary")
        det = IRCDetector(cfg)
        data = SyntheticDetectionData(img_hw=cfg.img_hw,
                                      stride=2 ** (len(cfg.stage_channels) + 1),
                                      n_classes=cfg.n_classes,
                                      n_anchors=cfg.n_anchors)
        print(f"\n=== {design} design: QAT ({args.steps} steps, "
              f"train_chips={args.train_chips}) ===")
        params = train(det, data, args.steps, args.batch, args.lr,
                       noise_cfg=noise_cfg, train_chips=args.train_chips,
                       resample_every=args.resample_every, obs=obs,
                       design=design)
        # deployment step (both designs): populate the digital stem's running
        # stats — eval mode normalizes with them — and, for the baseline, the
        # block BN stats the in-memory BN fold maps into bias cells
        calib = data.batch_for_step(999, args.batch * 4)
        params = det.calibrate_bn(params, calib.images)

        print(f"=== {design}: population MC ablation "
              f"({args.mc_chips} chips) ===")
        ev = data.batch_for_step(1000, args.batch * args.eval_batches)
        sweeps = run_ablation_detector(
            jax.random.PRNGKey(7000), det, params, ev.images, ev.boxes,
            ev.classes, ablations=ABLATION, mc=mc, obs=obs,
            stderr_target=args.stderr_target)
        results[design] = {}
        for name, res in sweeps.items():
            m = res.metrics["map50"]
            results[design][name] = (m["mean"] * 100, m["std"] * 100)
            obs.save_array(f"per_chip_map50_{design}_{name}",
                           res.per_chip["map50"])
            print(f"  {name:10s} mAP {m['mean'] * 100:5.1f} "
                  f"± {m['std'] * 100:4.1f}  "
                  f"({res.n_chips} chips, {res.chips_per_sec:.2f} chips/s "
                  f"steady, compile {res.compile_s:.1f}s)")

    print("\n=== Table II (synthetic-data analog, population mean) ===")
    header = "design     " + "".join(f"{n:>12s}" for n, _ in ABLATION)
    print(header)
    for design, r in results.items():
        row = f"{design:10s}" + "".join(f"{r[n][0]:12.1f}" for n, _ in ABLATION)
        print(row)
    summary = {}
    if {"proposed", "baseline"} <= results.keys():
        drop_p = results["proposed"]["ideal"][0] - results["proposed"]["all"][0]
        drop_b = results["baseline"]["ideal"][0] - results["baseline"]["all"][0]
        summary = {"drop_proposed": drop_p, "drop_baseline": drop_b}
        print(f"\nmAP drop under all effects: proposed {drop_p:.1f}, "
              f"baseline {drop_b:.1f} (paper: 3.85 vs catastrophic)")
    obs.finalize(status="ok", **summary)


if __name__ == "__main__":
    main()
