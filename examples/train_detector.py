"""End-to-end driver (the paper's task): train the IRC object detector with
QAT on synthetic IVS-geometry data, then evaluate the full structural
crossbar simulation under the paper's nonideal-effect ablation (Table II)
for BOTH designs:

  proposed : ternary 20/60/20, no BN, single-shot, extra bias
  baseline : binary + shared reference, in-memory BN, partial sums

Defaults are CPU-sized (32x32 images, ~200 steps, a few minutes); pass
--full for the paper's 1024x576 geometry (cluster-scale).

  PYTHONPATH=src python examples/train_detector.py --steps 200
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import yolo_irc
from repro.core import NonidealConfig
from repro.data.detection import SyntheticDetectionData
from repro.models import IRCDetector
from repro.optim import AdamWConfig, adamw_init, warmup_step_decay
from repro.train.det_loss import evaluate_map
from repro.train.steps import ensemble_key_for_step, make_det_qat_step

ABLATION = [
    ("ideal", NonidealConfig.none()),
    ("dev-var", NonidealConfig(device_variation=True)),
    ("dev+nl", NonidealConfig(device_variation=True, nonlinearity=True)),
    ("dev+nl+sa", NonidealConfig(device_variation=True, nonlinearity=True,
                                 sa_variation=True, sensing_range=True)),
    ("all", NonidealConfig.all()),
]


def train(det, data, steps, batch, lr, seed=0, noise_cfg=NonidealConfig.none(),
          train_chips=1, resample_every=1, key=None):
    """QAT on the shared step builder (`repro.train.steps.make_det_qat_step`).

    `train_chips=1` is the legacy single-draw surrogate; >=2 trains against a
    chip population (ensemble-aware QAT, paper Sec. V at population scale).
    `key` roots BOTH the per-step noise stream and the chip-population
    stream, so a run is reproducible from one key (defaults to the
    historical PRNGKey(1)).
    """
    params = det.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    step_fn = jax.jit(make_det_qat_step(
        det, train_chips=train_chips, cfg_ni=noise_cfg,
        opt_cfg=AdamWConfig(weight_decay=1e-3)))   # paper: AdamW, wd=1e-3
    root = jax.random.PRNGKey(1) if key is None else key

    t0 = time.time()
    for s in range(steps):
        b = data.batch_for_step(s, batch)
        lr_s = warmup_step_decay(s, base_lr=lr, warmup_steps=max(steps // 10, 1),
                                 decay_points=((int(steps * 0.7), lr / 10),
                                               (int(steps * 0.9), lr / 100)))
        params, opt, loss = step_fn(params, opt, b.images, b.targets, lr_s,
                                    jax.random.fold_in(root, s),
                                    ensemble_key_for_step(root, s,
                                                          resample_every))
        if s % max(steps // 10, 1) == 0:
            print(f"  step {s:4d}  loss {float(loss):8.4f} "
                  f"({time.time()-t0:5.1f}s)", flush=True)
    return params


def eval_map(det, params, data, n_batches, batch, cfg_ni, seeds, mode="eval"):
    """mAP over `seeds` nonideal-sample draws (paper: 10 seeds)."""
    maps = []
    for seed in range(seeds):
        preds, gt_b, gt_c = [], [], []
        for i in range(n_batches):
            b = data.batch_for_step(1000 + i, batch)
            pred = det.apply(params, b.images, mode=mode,
                             key=jax.random.PRNGKey(7000 + seed),
                             cfg_ni=cfg_ni)
            preds.extend(np.asarray(pred))
            gt_b.extend(b.boxes)
            gt_c.extend(b.classes)
        maps.append(evaluate_map(np.asarray(preds), gt_b, gt_c,
                                 det.cfg.n_anchors, det.cfg.n_classes) * 100)
    return float(np.mean(maps)), float(np.std(maps))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale 1024x576 geometry")
    ap.add_argument("--designs", default="proposed,baseline")
    ap.add_argument("--qat-noise", action="store_true",
                    help="variation-aware QAT: surrogate nonideal noise "
                         "during training (paper Sec. V)")
    ap.add_argument("--train-chips", type=int, default=1,
                    help="ensemble-aware QAT: chip realizations per step "
                         "(implies --qat-noise; 1 = legacy single draw)")
    ap.add_argument("--resample-every", type=int, default=1,
                    help="QAT steps between chip-population resamples")
    args = ap.parse_args()

    noise_cfg = (NonidealConfig.all()
                 if (args.qat_noise or args.train_chips > 1)
                 else NonidealConfig.none())
    results = {}
    for design in args.designs.split(","):
        cfg = (yolo_irc.proposed() if design == "proposed"
               else yolo_irc.baseline()) if args.full else \
            yolo_irc.smoke("ternary" if design == "proposed" else "binary")
        det = IRCDetector(cfg)
        data = SyntheticDetectionData(img_hw=cfg.img_hw,
                                      stride=2 ** (len(cfg.stage_channels) + 1),
                                      n_classes=cfg.n_classes,
                                      n_anchors=cfg.n_anchors)
        print(f"\n=== {design} design: QAT ({args.steps} steps, "
              f"train_chips={args.train_chips}) ===")
        params = train(det, data, args.steps, args.batch, args.lr,
                       noise_cfg=noise_cfg, train_chips=args.train_chips,
                       resample_every=args.resample_every)
        # deployment step (both designs): populate the digital stem's running
        # stats — eval mode normalizes with them — and, for the baseline, the
        # block BN stats the in-memory BN fold maps into bias cells
        calib = data.batch_for_step(999, args.batch * 4)
        params = det.calibrate_bn(params, calib.images)

        print(f"=== {design}: structural-sim ablation "
              f"({args.seeds} nonideal seeds) ===")
        results[design] = {}
        for name, cfg_ni in ABLATION:
            m, s = eval_map(det, params, data, args.eval_batches, args.batch,
                            cfg_ni, seeds=1 if name == "ideal" else args.seeds)
            results[design][name] = (m, s)
            print(f"  {name:10s} mAP {m:5.1f} ± {s:4.1f}")

    print("\n=== Table II (synthetic-data analog) ===")
    header = "design     " + "".join(f"{n:>12s}" for n, _ in ABLATION)
    print(header)
    for design, r in results.items():
        row = f"{design:10s}" + "".join(f"{r[n][0]:12.1f}" for n, _ in ABLATION)
        print(row)
    if {"proposed", "baseline"} <= results.keys():
        drop_p = results["proposed"]["ideal"][0] - results["proposed"]["all"][0]
        drop_b = results["baseline"]["ideal"][0] - results["baseline"]["all"][0]
        print(f"\nmAP drop under all effects: proposed {drop_p:.1f}, "
              f"baseline {drop_b:.1f} (paper: 3.85 vs catastrophic)")


if __name__ == "__main__":
    main()
