"""Quickstart: the paper's IRC macro in 60 lines.

Maps a ternary layer onto the 1024x1024 crossbar, runs the full structural
simulation under each nonideal effect (Table II columns), shows the
single-shot vs partial-sum difference (Fig. 8), and calibrates the extra
bias (Table I).  Runs in ~30 s on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (DEFAULT_MACRO, NonidealConfig, ternary_quantize,
                        ternary_planes, crossbar_forward,
                        ideal_ternary_matmul, calibrate_bias,
                        layer_current_stats, ternary_fractions)
from repro.kernels import irc_mvm_from_mapped


def main():
    key = jax.random.PRNGKey(0)
    fan_in, n_out, batch = 540, 64, 64      # one YOLO group: 3*3*60 inputs

    # --- ternary weights with the paper's 20/60/20 regulation -------------
    w = ternary_quantize(jax.random.normal(key, (fan_in, n_out)))
    print("weight fractions (-1/0/+1):",
          [f"{float(f):.2f}" for f in ternary_fractions(w)])
    mapped = ternary_planes(w, bias_rows=32)
    x = (jax.random.uniform(jax.random.PRNGKey(1),
                            (batch, fan_in)) > 0.5).astype(jnp.float32)
    ref_sign = ideal_ternary_matmul(x, w) > 0

    # --- each nonideal effect, one at a time (Table II structure) ---------
    effects = {
        "ideal": NonidealConfig.none(),
        "device variation": NonidealConfig(device_variation=True),
        "+ nonlinearity": NonidealConfig(device_variation=True,
                                         nonlinearity=True),
        "+ SA variation / range": NonidealConfig(device_variation=True,
                                                 nonlinearity=True,
                                                 sa_variation=True,
                                                 sensing_range=True),
        "+ IR drop (all)": NonidealConfig.all(),
    }
    print("\nbit agreement vs ideal sign (proposed design, single-shot):")
    for name, cfg in effects.items():
        out = crossbar_forward(jax.random.PRNGKey(2), x, mapped, cfg=cfg)
        agree = float(jnp.mean((out > 0.5) == ref_sign))
        print(f"  {name:26s} {agree:6.1%}")

    # --- single-shot vs partial-sum (Fig. 8) ------------------------------
    cfg_nl = NonidealConfig(nonlinearity=True)
    for acc in ("single_shot", "partial_sum"):
        out = crossbar_forward(jax.random.PRNGKey(2), x, mapped, cfg=cfg_nl,
                               accumulation=acc)
        agree = float(jnp.mean((out > 0.5) == ref_sign))
        print(f"nonlinearity with {acc:12s}: {agree:6.1%}")

    # --- extra-bias calibration (Table I) ----------------------------------
    # sparse activations (the paper's Table I regime: line currents sit near
    # the 35 uA sensing floor, e.g. Layer3_0's 29.28% failures)
    x_sparse = (jax.random.uniform(jax.random.PRNGKey(5),
                                   (batch, fan_in)) > 0.75).astype(jnp.float32)
    ip, ineg, p = layer_current_stats(jax.random.PRNGKey(3), x_sparse,
                                      ternary_planes(w, 0))
    best, report = calibrate_bias(ip, ineg, p)
    print(f"\nbias calibration (sparse layer): best extra bias = {best} units")
    for b in sorted({0, best}):
        r = report[b]
        print(f"  bias {b:2d}: below-lower-bound {r['below_lower_bound']:.2%}"
              f"  sensing-variation {r['sensing_variation']:.2%}")

    # --- the Pallas kernel path matches the structural sim ----------------
    out_core = crossbar_forward(jax.random.PRNGKey(4), x, mapped,
                                cfg=NonidealConfig.all())
    out_kernel = irc_mvm_from_mapped(jax.random.PRNGKey(4), x, mapped,
                                     NonidealConfig.all(), DEFAULT_MACRO)
    print(f"\nPallas kernel vs structural sim agreement: "
          f"{float(jnp.mean(out_core == out_kernel)):.1%}")


if __name__ == "__main__":
    main()
