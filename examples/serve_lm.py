"""Batched serving with the slot-wave engine: loads (or initializes) an LM,
serves a batch of prompt requests, reports per-request outputs + throughput.

  PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b --requests 6
"""
import argparse
import time

import jax

from repro.configs.registry import get_config, list_archs
from repro.models import LM
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, "smoke")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    engine = ServeEngine(lm, params, batch_slots=args.slots, max_len=128,
                         temperature=args.temperature)

    rng = jax.random.PRNGKey(1)
    prompts = []
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        n = 3 + i % 5
        prompts.append([int(t) for t in
                        jax.random.randint(k, (n,), 0, cfg.vocab_size)])

    t0 = time.time()
    results = engine.generate(prompts, max_new_tokens=args.max_new)
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in results)
    for i, r in enumerate(results):
        print(f"req {i}: prompt={r.prompt} -> {r.tokens}")
    print(f"\n{len(results)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new/dt:.1f} tok/s, {args.slots} slots, "
          f"arch={args.arch} smoke)")


if __name__ == "__main__":
    main()
