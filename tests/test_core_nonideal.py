"""Unit tests for the nonideal-effect models (paper Sec. III)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DEFAULT_MACRO, NonidealConfig, wl_point,
                        nonlinearity_ratio,
                        ir_drop_factors, apply_ir_drop, sample_variation_mask,
                        sa_required_diff, sensing_failure, resolve_sa)


class TestNonlinearity:
    def test_ratio_zero_is_one(self):
        assert float(nonlinearity_ratio(jnp.array(0.0))) == 1.0

    def test_paper_coefficients_spot_values(self):
        # direct evaluation of the published piecewise quartics
        def poly_lo(p):
            return (1.0286e-8 * p**4 - 3.79e-6 * p**3 + 5.3e-4 * p**2
                    - 3.92e-2 * p + 2.5)
        def poly_hi(p):
            return (1.8063e-11 * p**4 - 3.204e-8 * p**3 + 2.2495e-5 * p**2
                    - 8.057e-3 * p + 1.707)
        for p in (1, 30, 77, 140):
            np.testing.assert_allclose(float(nonlinearity_ratio(jnp.array(p))),
                                       poly_lo(p), rtol=1e-5)
        for p in (141, 205, 300):
            np.testing.assert_allclose(float(nonlinearity_ratio(jnp.array(p))),
                                       poly_hi(p), rtol=1e-5)

    def test_clamped_beyond_fit_domain(self):
        r320 = float(nonlinearity_ratio(jnp.array(320.0)))
        r1000 = float(nonlinearity_ratio(jnp.array(1000.0)))
        assert r320 == pytest.approx(r1000)
        assert 0.0 < r1000 < 1.0

    def test_current_monotone_within_pieces(self):
        # physical accumulated current p*ratio(p) is monotone within each
        # polynomial piece (the published fit has a small junction glitch)
        p = jnp.arange(0, 141)
        cur = p * nonlinearity_ratio(p)
        assert bool(jnp.all(jnp.diff(cur) > 0))
        p = jnp.arange(141, 321)
        cur = p * nonlinearity_ratio(p)
        assert bool(jnp.all(jnp.diff(cur) > 0))

    def test_small_p_inflation(self):
        # Fig. 8: small partial sums are inflated (ratio > 1 for small p)
        assert float(nonlinearity_ratio(jnp.array(3.0))) > 1.5


class TestDeviceVariation:
    def test_lognormal_median_and_sigma(self):
        key = jax.random.PRNGKey(0)
        m = sample_variation_mask(key, (200_000,), sigma=0.4245)
        logm = jnp.log(m)
        assert float(jnp.median(m)) == pytest.approx(1.0, abs=0.02)
        assert float(jnp.std(logm)) == pytest.approx(0.4245, rel=0.02)

    def test_law_of_large_numbers(self):
        # Sec. III-B: summing 1024 cells tightens the relative distribution
        key = jax.random.PRNGKey(1)
        m = sample_variation_mask(key, (2000, 1024), sigma=0.4245)
        single_rel = float(jnp.std(m[:, 0]) / jnp.mean(m[:, 0]))
        summed = jnp.sum(m, axis=1)
        sum_rel = float(jnp.std(summed) / jnp.mean(summed))
        assert sum_rel < single_rel / 10  # sqrt(1024)=32x tightening

    def test_sigma_tracks_wl_voltage(self):
        # lower WL voltage -> higher sigma (paper Fig. 14 x-axis)
        _, s_low = wl_point(0.40)
        _, s_mid = wl_point(0.44)
        _, s_high = wl_point(0.48)
        assert s_low > s_mid > s_high
        assert s_mid == pytest.approx(0.4245)


class TestIRDrop:
    def test_no_drop_with_zero_alpha(self):
        blocks = jnp.ones((4, 32))
        f = ir_drop_factors(blocks, alpha=0.0)
        np.testing.assert_allclose(np.asarray(f), 1.0)

    def test_drop_increases_with_distance(self):
        # Fig. 10 blue line: same 32-LRS block placed farther from the
        # driver loses more current
        alpha = DEFAULT_MACRO.ir_alpha
        drops = []
        for pos in range(0, 32, 8):
            blocks = jnp.zeros((32,)).at[pos].set(32.0)
            total = float(apply_ir_drop(blocks, alpha))
            drops.append(32.0 - total)
        assert all(b >= a - 1e-6 for a, b in zip(drops, drops[1:]))
        assert drops[-1] > drops[0]

    def test_more_current_more_drop(self):
        # Fig. 10 red line: 160 cells in blocks 0-4 drop more than 32 in one
        alpha = DEFAULT_MACRO.ir_alpha
        one = jnp.zeros((32,)).at[4].set(32.0)
        five = jnp.zeros((32,)).at[:5].set(32.0)
        loss_one = 32.0 - float(apply_ir_drop(one, alpha))
        loss_five = 160.0 - float(apply_ir_drop(five, alpha))
        assert loss_five > loss_one

    def test_block0_sees_no_wire(self):
        blocks = jnp.zeros((32,)).at[0].set(32.0)
        f = ir_drop_factors(blocks, DEFAULT_MACRO.ir_alpha)
        assert float(f[0]) == pytest.approx(1.0)


class TestSA:
    def test_required_diff_grows_with_p(self):
        # Fig. 9: more activated LRS cells -> larger required difference
        g = sa_required_diff(jnp.array([0.0, 100.0, 300.0]))
        assert float(g[0]) < float(g[1]) < float(g[2])
        assert float(g[0]) == pytest.approx(2.0)

    def test_sensing_failure_bounds(self):
        spec = DEFAULT_MACRO
        lo, hi = spec.sense_low_units, spec.sense_high_units
        i_pos = jnp.array([lo - 1.0, lo + 1.0, hi + 1.0, 100.0])
        i_neg = jnp.array([100.0, lo + 1.0, 100.0, 100.0])
        f = sensing_failure(i_pos, i_neg, spec)
        assert f.tolist() == [True, False, True, False]

    def test_resolve_ideal(self):
        key = jax.random.PRNGKey(0)
        out = resolve_sa(key, jnp.array([100.0, 50.0]), jnp.array([50.0, 100.0]),
                         jnp.array([150.0, 150.0]), NonidealConfig.none())
        assert out.tolist() == [1.0, 0.0]

    def test_out_of_range_randomized(self):
        # far below the sensing floor -> output is a coin flip
        key = jax.random.PRNGKey(0)
        n = 2000
        i_pos = jnp.full((n,), 5.0)
        i_neg = jnp.full((n,), 2.0)
        cfg = NonidealConfig(sensing_range=True)
        out = resolve_sa(key, i_pos, i_neg, i_pos + i_neg, cfg)
        assert 0.4 < float(jnp.mean(out)) < 0.6
