"""Training stack tests: optimizer, schedules, data determinism, trainer
loop with checkpoint/restart (fault tolerance), serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data import SyntheticLMData
from repro.data.detection import SyntheticDetectionData
from repro.models import LM
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         warmup_step_decay, global_norm)
from repro.serve import ServeEngine
from repro.train import make_train_step
from repro.train.steps import init_train_state
from repro.train.trainer import Trainer, TrainerConfig
from repro.ckpt import CheckpointManager, save_pytree, restore_pytree, latest_step


class TestAdamW:
    def test_reduces_quadratic(self):
        params = {"w": jnp.ones((8,)) * 5.0}
        state = adamw_init(params)
        cfg = AdamWConfig(weight_decay=0.0)
        for _ in range(200):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = adamw_update(grads, state, params,
                                            jnp.float32(0.05), cfg)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.5

    def test_weight_decay_decoupled(self):
        params = {"w": jnp.ones((4,))}
        state = adamw_init(params)
        grads = {"w": jnp.zeros((4,))}
        cfg = AdamWConfig(weight_decay=0.1, grad_clip=0.0)
        params, _, _ = adamw_update(grads, state, params, jnp.float32(0.1), cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), 0.99, rtol=1e-5)

    def test_grad_clip(self):
        grads = {"w": jnp.ones((100,)) * 10}
        assert float(global_norm(grads)) == pytest.approx(100.0)

    def test_bf16_params_f32_moments(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = adamw_init(params)
        assert state["m"]["w"].dtype == jnp.float32
        grads = {"w": jnp.ones((4,), jnp.bfloat16)}
        new_p, new_s, _ = adamw_update(grads, state, params, jnp.float32(0.01))
        assert new_p["w"].dtype == jnp.bfloat16
        assert new_s["v"]["w"].dtype == jnp.float32


class TestSchedule:
    def test_paper_schedule_shape(self):
        # warmup 1e-5 -> 1e-4, then steps at the decay points
        assert float(warmup_step_decay(0)) == pytest.approx(1e-5)
        assert float(warmup_step_decay(500)) == pytest.approx(1e-4)
        assert float(warmup_step_decay(9000)) == pytest.approx(1e-5)
        assert float(warmup_step_decay(12000)) == pytest.approx(1e-6)


class TestData:
    def test_deterministic_and_restart_exact(self):
        d = SyntheticLMData(vocab_size=128, seq_len=32, global_batch=4)
        a = d.batch_for_step(7)
        b = d.batch_for_step(7)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        c = d.batch_for_step(8)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(c["tokens"]))

    def test_labels_are_shifted_stream(self):
        d = SyntheticLMData(vocab_size=128, seq_len=32, global_batch=2)
        b = d.batch_for_step(0)
        assert b["tokens"].shape == (2, 32) and b["labels"].shape == (2, 32)

    def test_host_sharding_disjoint(self):
        d = SyntheticLMData(vocab_size=128, seq_len=16, global_batch=8)
        h0 = d.batch_for_step(3, host_id=0, n_hosts=2)
        h1 = d.batch_for_step(3, host_id=1, n_hosts=2)
        assert h0["tokens"].shape == (4, 16)
        assert not np.array_equal(np.asarray(h0["tokens"]),
                                  np.asarray(h1["tokens"]))

    def test_detection_targets_consistent(self):
        d = SyntheticDetectionData(img_hw=(32, 32), stride=8)
        batch = d.batch_for_step(0, batch=2)
        assert batch.images.shape == (2, 32, 32, 3)
        assert batch.targets["obj"].shape == (2, 4, 4, 5)
        assert float(jnp.sum(batch.targets["obj"])) >= 1


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
        save_pytree(tree, tmp_path, step=3)
        assert latest_step(tmp_path) == 3
        out = restore_pytree(jax.eval_shape(lambda: tree), tmp_path)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_atomic_no_partial_visible(self, tmp_path):
        # a .tmp directory must never be picked up by latest_step
        (tmp_path / "step_000000009.tmp").mkdir(parents=True)
        assert latest_step(tmp_path) is None

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        tree = {"w": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            mgr.save(tree, s)
        steps = sorted(int(p.name.split("_")[1])
                       for p in tmp_path.iterdir() if p.is_dir())
        assert steps == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=3)
        mgr.save_async({"w": jnp.ones(4)}, 1)
        mgr.wait()
        assert latest_step(tmp_path) == 1


class TestTrainerEndToEnd:
    def _setup(self, tmp_path, total_steps=6):
        cfg = get_config("phi3-medium-14b", "smoke")
        lm = LM(cfg)
        data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=16,
                               global_batch=4)
        state = init_train_state(lm, jax.random.PRNGKey(0))
        step_fn = make_train_step(lm, remat="none",
                                  lr_fn=lambda s: jnp.float32(3e-3))
        tcfg = TrainerConfig(total_steps=total_steps, ckpt_every=3,
                             ckpt_dir=str(tmp_path), log_every=0)
        return Trainer(tcfg, step_fn, lambda s: data.batch_for_step(s), state)

    def test_loss_decreases(self, tmp_path):
        tr = self._setup(tmp_path, total_steps=30)
        hist = tr.run()
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        assert last < first, (first, last)

    def test_restart_resumes_from_checkpoint(self, tmp_path):
        tr = self._setup(tmp_path, total_steps=6)
        tr.run()
        assert latest_step(tmp_path) == 6
        # "node failure": new trainer process resumes at step 6 and
        # continues to 9 without replaying steps
        tr2 = self._setup(tmp_path, total_steps=9)
        hist2 = tr2.run()
        assert hist2[0]["step"] == 6
        assert len(hist2) == 3


class TestServeEngine:
    def test_batched_generation(self):
        cfg = get_config("phi3-medium-14b", "smoke")
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        eng = ServeEngine(lm, params, batch_slots=2, max_len=32)
        prompts = [[1, 2, 3], [4, 5], [6]]
        out = eng.generate(prompts, max_new_tokens=4)
        assert len(out) == 3
        for r in out:
            assert len(r.tokens) == 4
            assert all(0 <= t < cfg.vocab_size for t in r.tokens)

    def test_greedy_deterministic(self):
        cfg = get_config("phi3-medium-14b", "smoke")
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        eng = ServeEngine(lm, params, batch_slots=1, max_len=32)
        a = eng.generate([[1, 2, 3]], max_new_tokens=5)[0].tokens
        b = eng.generate([[1, 2, 3]], max_new_tokens=5)[0].tokens
        assert a == b

    def test_sampling_independent_of_earlier_waves(self):
        """Regression (repro.analysis KEY004): sampling keys were a split
        chain through `self.key`, so a request's draws depended on how many
        tokens EARLIER waves generated.  Keys are now fold_in(root, wave,
        step): wave 1's draws must not change when wave 0 generates a
        different number of tokens."""
        cfg = get_config("phi3-medium-14b", "smoke")
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))

        def second_wave_tokens(first_wave_len: int):
            eng = ServeEngine(lm, params, batch_slots=1, max_len=32,
                              temperature=1.0, seed=7)
            eng.generate([[1, 2]], max_new_tokens=first_wave_len)
            return eng.generate([[3, 4, 5]], max_new_tokens=6)[0].tokens

        assert second_wave_tokens(2) == second_wave_tokens(9)
