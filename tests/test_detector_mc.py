"""Whole-network chip-ensemble MC (repro.mc.detector_mc) + the detector
eval-path correctness fixes it depends on: eval-mode BN running stats,
scheme-derived QAT noise fractions, sign-preserving BN calibration, and the
DetectorEnsemble fold_in key discipline (chip c bit-identical to the
single-chip structural path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import yolo_irc
from repro.core import NonidealConfig
from repro.core.crossbar import variation_noise_std
from repro.core.ternary import binary_activation
from repro.data.detection import SyntheticDetectionData
from repro.models import IRCDetector
from repro.models.detector import DetectorConfig
from repro.mc import (McConfig, build_detector_ensemble, run_mc_detector,
                      run_ablation_detector)
from repro.train.det_loss import evaluate_map_per_chip


def _detector(scheme="ternary", calib_batch=4, seed=0):
    cfg = yolo_irc.smoke(scheme)
    det = IRCDetector(cfg)
    params = det.init(jax.random.PRNGKey(seed))
    calib = jax.random.uniform(jax.random.PRNGKey(seed + 1),
                               (calib_batch, 32, 32, 3))
    params = det.calibrate_bn(params, calib)
    return det, params


class TestEvalPathFixes:
    def test_eval_batch_size_invariance(self):
        """Eval-mode outputs for one image must not depend on which other
        images share the batch (stem BN must use running stats, not batch
        statistics — MC chunking would otherwise change the metric)."""
        det, params = _detector("ternary")
        imgs = jax.random.uniform(jax.random.PRNGKey(2), (8, 32, 32, 3))
        key = jax.random.PRNGKey(3)
        out8 = det.apply(params, imgs, mode="eval", key=key)
        out1 = det.apply(params, imgs[:1], mode="eval", key=key)
        np.testing.assert_array_equal(np.asarray(out8[:1]), np.asarray(out1))

    def test_calibrate_bn_populates_stem_stats_both_designs(self):
        for scheme in ("ternary", "binary"):
            cfg = yolo_irc.smoke(scheme)
            det = IRCDetector(cfg)
            params = det.init(jax.random.PRNGKey(0))
            imgs = jax.random.uniform(jax.random.PRNGKey(1), (4, 32, 32, 3))
            cal = det.calibrate_bn(params, imgs)
            bn = cal["stem_bn"]
            assert float(jnp.max(jnp.abs(bn["mean"]))) > 0.0, scheme
            assert float(jnp.max(jnp.abs(bn["var"] - 1.0))) > 0.0, scheme

    def test_calibrate_bn_gamma_sign_invariance(self):
        """The in-memory BN fold is sign-preserving via |gamma| (train path
        and mapping); the calibration propagation must match, so flipping a
        block gamma's sign cannot change downstream calibrated stats."""
        cfg = yolo_irc.smoke("binary")
        det = IRCDetector(cfg)
        params = det.init(jax.random.PRNGKey(0))
        imgs = jax.random.uniform(jax.random.PRNGKey(1), (4, 32, 32, 3))
        # give block gammas mixed signs, then compare against |gamma|
        flipped = jax.tree.map(lambda x: x, params)
        for name in ("s0b0", "s1b0"):
            blk = dict(flipped[name])
            bn = dict(blk["bn"])
            sign = jnp.where(jnp.arange(bn["gamma"].shape[0]) % 2 == 0,
                             -1.0, 1.0)
            bn["gamma"] = bn["gamma"] * sign
            blk["bn"] = bn
            flipped[name] = blk
        cal_a = det.calibrate_bn(params, imgs)
        cal_b = det.calibrate_bn(flipped, imgs)
        for name in ("s0b0", "s1b0"):
            for stat in ("mean", "var"):
                np.testing.assert_array_equal(
                    np.asarray(cal_a[name]["bn"][stat]),
                    np.asarray(cal_b[name]["bn"][stat]), err_msg=name)
        # and the deployed eval path agrees too (|gamma| everywhere)
        key = jax.random.PRNGKey(5)
        out_a = det.apply(cal_a, imgs, mode="eval", key=key,
                          cfg_ni=NonidealConfig.all())
        out_b = det.apply(cal_b, imgs, mode="eval", key=key,
                          cfg_ni=NonidealConfig.all())
        np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))

    def test_qat_noise_fraction_follows_scheme(self):
        """The QAT surrogate's activated-LRS fraction must come from the
        quantized weights (binary -> ~1.0), not a hardcoded ternary 0.4."""
        cfg = DetectorConfig(img_hw=(16, 16), stage_channels=(60,),
                             blocks_per_stage=(1,), scheme="binary",
                             use_bn=False, n_anchors=2)
        det = IRCDetector(cfg)
        params = det.init(jax.random.PRNGKey(0))
        x = (jax.random.uniform(jax.random.PRNGKey(1), (2, 8, 8, 60))
             > 0.5).astype(jnp.float32)
        key = jax.random.PRNGKey(2)
        cfg_ni = NonidealConfig(device_variation=True)
        out = det._gconv(params["s0b0"], x, 60, 60, mode="train", key=key,
                         cfg_ni=cfg_ni)

        def reference(frac_fn):
            wq = det._gconv_weights(params["s0b0"], 60, 60)
            pre = jax.lax.conv_general_dilated(
                x, wq[..., 0], (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            frac = frac_fn(wq)
            p_pair = (jnp.sum(x, axis=-1, keepdims=True) * frac
                      * 9.0 / 60 * det.cfg.group)   # exact op order of _gconv
            std = variation_noise_std(p_pair, det.spec.sigma_lrs)
            return binary_activation(
                pre + std * jax.random.normal(key, pre.shape))

        fixed = reference(lambda wq: jnp.mean(jnp.abs(wq)))   # == 1.0 here
        np.testing.assert_array_equal(np.asarray(out), np.asarray(fixed))
        buggy = reference(lambda wq: 0.4)                     # pre-PR value
        assert not np.array_equal(np.asarray(out), np.asarray(buggy))


class TestDetectorEnsemble:
    @pytest.mark.parametrize("scheme", ["ternary", "binary"])
    def test_bit_identity_vs_single_chip_eval(self, scheme):
        """fold_in key discipline: chip c of the ensemble path ==
        apply(mode="eval", key=fold_in(key, c)) bit-for-bit, both designs
        (ternary single-shot and binary partial-sum + in-memory BN)."""
        det, params = _detector(scheme)
        imgs = jax.random.uniform(jax.random.PRNGKey(2), (2, 32, 32, 3))
        key = jax.random.PRNGKey(21)
        cfg_ni = NonidealConfig.all()
        ens = build_detector_ensemble(key, det, params, 3, cfg=cfg_ni)
        out = det.apply(params, imgs, mode="ensemble", ensemble=ens,
                        cfg_ni=cfg_ni)
        assert out.shape[0] == 3
        for c in range(3):
            ref = det.apply(params, imgs, mode="eval",
                            key=jax.random.fold_in(key, c), cfg_ni=cfg_ni)
            np.testing.assert_array_equal(np.asarray(out[c]),
                                          np.asarray(ref))

    def test_ensemble_chips_distinct(self):
        det, params = _detector("ternary")
        ens = build_detector_ensemble(jax.random.PRNGKey(0), det, params, 2)
        g0 = ens.layers["s0b0"][0]
        assert float(jnp.max(jnp.abs(g0.ep[0] - g0.ep[1]))) > 0.0

    def test_evaluate_map_per_chip_shapes(self):
        data = SyntheticDetectionData(img_hw=(32, 32), stride=8)
        b = data.batch_for_step(0, batch=2)
        preds = np.asarray(jax.random.normal(jax.random.PRNGKey(0),
                                             (3, 2, 4, 4, 40)))
        vals = evaluate_map_per_chip(preds, b.boxes, b.classes, 5, 3)
        assert vals.shape == (3,) and vals.dtype == np.float32
        assert np.all((vals >= 0.0) & (vals <= 1.0))


class TestRunMcDetector:
    @pytest.mark.slow
    def test_population_map_stream(self):
        """Acceptance: >= 16 chips of the whole detector in a jitted chunk
        stream, mAP@0.5 mean/std/quantiles out, chunking invisible."""
        det, params = _detector("ternary")
        data = SyntheticDetectionData(img_hw=det.cfg.img_hw,
                                      stride=det.cfg.strides,
                                      n_classes=det.cfg.n_classes,
                                      n_anchors=det.cfg.n_anchors)
        b = data.batch_for_step(1000, 2)
        key = jax.random.PRNGKey(7)
        mc = McConfig(n_chips=16, chunk_size=16, cfg=NonidealConfig.all())
        res = run_mc_detector(key, det, params, b.images, b.boxes,
                              b.classes, mc=mc)
        m = res.metrics["map50"]
        assert res.n_chips == 16 and m["count"] == 16.0
        assert 0.0 <= m["mean"] <= 1.0 and m["std"] >= 0.0
        assert m["q05"] <= m["q50"] <= m["q95"]
        assert res.per_chip["map50"].shape == (16,)
        # chip c is keyed by fold_in(key, c) regardless of chunk layout
        res4 = run_mc_detector(key, det, params, b.images, b.boxes,
                               b.classes,
                               mc=dataclasses.replace(mc, chunk_size=4))
        np.testing.assert_array_equal(res.per_chip["map50"],
                                      res4.per_chip["map50"])

    @pytest.mark.slow
    def test_pipeline_bit_identical_to_serial(self):
        """The double-buffered pipeline (hoisted planes, in-trace sampling,
        next-chunk dispatch overlapping host mAP) must reproduce the serial
        loop's per-chip mAPs BIT-FOR-BIT — threefry sampling inside the
        fused chunk jit is bitwise-deterministic, so moving it in-trace and
        reordering dispatch against host work cannot change a single chip."""
        det, params = _detector("ternary")
        data = SyntheticDetectionData(img_hw=det.cfg.img_hw,
                                      stride=det.cfg.strides,
                                      n_classes=det.cfg.n_classes,
                                      n_anchors=det.cfg.n_anchors)
        b = data.batch_for_step(1000, 2)
        key = jax.random.PRNGKey(11)
        mc = McConfig(n_chips=6, chunk_size=2, cfg=NonidealConfig.all())
        res_p = run_mc_detector(key, det, params, b.images, b.boxes,
                                b.classes, mc=mc, pipeline=True)
        res_s = run_mc_detector(key, det, params, b.images, b.boxes,
                                b.classes, mc=mc, pipeline=False)
        np.testing.assert_array_equal(res_p.per_chip["map50"],
                                      res_s.per_chip["map50"])
        assert res_p.metrics["map50"] == res_s.metrics["map50"]
        # telemetry: both paths account the full loop body wall
        for r in (res_p, res_s):
            assert r.device_s >= 0.0 and r.host_s >= 0.0
            assert r.device_s + r.host_s <= r.wall_s + 1e-6

    @pytest.mark.slow
    def test_pipeline_early_stop_same_chunk_as_serial(self):
        """stderr_target early stop triggers at the same chunk boundary with
        identical surviving moments whether or not the next chunk was
        already dispatched (the pipeline only ever wastes the one inflight
        chunk, it never folds it in)."""
        det, params = _detector("ternary")
        data = SyntheticDetectionData(img_hw=det.cfg.img_hw,
                                      stride=det.cfg.strides,
                                      n_classes=det.cfg.n_classes,
                                      n_anchors=det.cfg.n_anchors)
        b = data.batch_for_step(1000, 2)
        key = jax.random.PRNGKey(11)
        mc = McConfig(n_chips=8, chunk_size=2, cfg=NonidealConfig.all())
        kw = dict(mc=mc, stderr_target=1e9)   # converges at first check
        res_p = run_mc_detector(key, det, params, b.images, b.boxes,
                                b.classes, pipeline=True, **kw)
        res_s = run_mc_detector(key, det, params, b.images, b.boxes,
                                b.classes, pipeline=False, **kw)
        assert res_p.n_chips == res_s.n_chips < 8
        np.testing.assert_array_equal(res_p.per_chip["map50"],
                                      res_s.per_chip["map50"])
        assert res_p.metrics["map50"] == res_s.metrics["map50"]

    @pytest.mark.slow
    def test_ablation_detector_runs_all_columns(self):
        det, params = _detector("ternary")
        data = SyntheticDetectionData(img_hw=det.cfg.img_hw,
                                      stride=det.cfg.strides,
                                      n_classes=det.cfg.n_classes,
                                      n_anchors=det.cfg.n_anchors)
        b = data.batch_for_step(1000, 2)
        res = run_ablation_detector(
            jax.random.PRNGKey(3), det, params, b.images, b.boxes,
            b.classes,
            ablations=(("ideal", NonidealConfig.none()),
                       ("all", NonidealConfig.all())),
            mc=McConfig(n_chips=4, chunk_size=4))
        assert set(res) == {"ideal", "all"}
        for r in res.values():
            assert r.per_chip["map50"].shape == (4,)
