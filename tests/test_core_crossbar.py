"""Tests for quantizers, mapping, crossbar forward, and calibration
(paper Secs. IV-B, Table I) — the system invariants the paper argues for."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MacroSpec, NonidealConfig,
                        ternary_quantize, binary_quantize, binary_activation,
                        ternary_fractions, ternary_planes, binary_planes,
                        extend_inputs, fold_bn_to_bias_units,
                        crossbar_forward, ideal_ternary_matmul,
                        IRCLinear, IRCLinearConfig,
                        calibrate_bias, sa_error_rates, layer_current_stats)


class TestQuantizers:
    def test_ternary_fractions_regulated(self):
        # paper Sec. IV-B.1: 20/60/20 distribution regulation
        w = jax.random.normal(jax.random.PRNGKey(0), (4096,))
        f = ternary_fractions(ternary_quantize(w))
        np.testing.assert_allclose(np.asarray(f), [0.2, 0.6, 0.2], atol=0.01)

    def test_ternary_grouped_axis(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (8, 512))
        wt = ternary_quantize(w, axis=(1,))
        for g in range(8):
            f = ternary_fractions(wt[g])
            np.testing.assert_allclose(np.asarray(f), [0.2, 0.6, 0.2], atol=0.02)

    def test_ste_gradients_flow(self):
        w = jax.random.normal(jax.random.PRNGKey(2), (64, 8))
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
        def loss(w):
            return jnp.sum(x @ ternary_quantize(w))
        g = jax.grad(loss)(w)
        assert float(jnp.sum(jnp.abs(g))) > 0.0
        # clipped STE: no gradient far outside [-1, 1]
        g2 = jax.grad(lambda w: jnp.sum(ternary_quantize(w)))(jnp.full((4,), 5.0))
        np.testing.assert_allclose(np.asarray(g2), 0.0)

    def test_binary_activation_range(self):
        x = jnp.array([-2.0, -0.1, 0.0, 0.1, 2.0])
        np.testing.assert_allclose(np.asarray(binary_activation(x)),
                                   [0, 0, 0, 1, 1])


class TestMapping:
    def test_ternary_plane_semantics(self):
        w = jnp.array([[1.0], [-1.0], [0.0]])
        m = ternary_planes(w)
        np.testing.assert_allclose(np.asarray(m.g_pos[:, 0]), [1, 0, 0])
        np.testing.assert_allclose(np.asarray(m.g_neg[:, 0]), [0, 1, 0])

    def test_bias_rows_common_mode(self):
        # bias rows are LRS on BOTH planes -> differential unchanged
        w = ternary_quantize(jax.random.normal(jax.random.PRNGKey(0), (128, 16)))
        x = (jax.random.uniform(jax.random.PRNGKey(1), (4, 128)) > 0.5
             ).astype(jnp.float32)
        d0 = crossbar_forward(jax.random.PRNGKey(2), x, ternary_planes(w, 0),
                              output="diff")
        d32 = crossbar_forward(jax.random.PRNGKey(2), x, ternary_planes(w, 32),
                               output="diff")
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d32), atol=0.02)

    def test_binary_reference_line_current(self):
        # reference bit-line carries ~p/2 for p activated rows
        w = binary_quantize(jax.random.normal(jax.random.PRNGKey(0), (512, 4)))
        m = binary_planes(w)
        x = jnp.ones((1, 512))
        ref_current = x @ m.g_neg
        np.testing.assert_allclose(np.asarray(ref_current), 256.0)

    def test_binary_mapping_computes_sign(self):
        w = binary_quantize(jax.random.normal(jax.random.PRNGKey(3), (256, 8)))
        x = (jax.random.uniform(jax.random.PRNGKey(4), (16, 256)) > 0.5
             ).astype(jnp.float32)
        out = crossbar_forward(jax.random.PRNGKey(5), x, binary_planes(w))
        # sign(I_conv - I_ref) == sign(x @ w) when x@w != 0
        ref = x @ w
        mask = jnp.abs(ref) > 1.0
        agree = jnp.mean((out > 0.5) == (ref > 0), where=mask)
        assert float(agree) > 0.99

    def test_bn_folding_matches_bn_sign(self):
        key = jax.random.PRNGKey(6)
        y = jax.random.normal(key, (1000,)) * 10
        gamma, beta = jnp.array(2.0), jnp.array(1.5)
        mean, var = jnp.array(3.0), jnp.array(4.0)
        bn_out = gamma * (y - mean) / jnp.sqrt(var + 1e-5) + beta
        bias = fold_bn_to_bias_units(gamma, beta, mean, var)
        np.testing.assert_array_equal(np.asarray(bn_out > 0),
                                      np.asarray(y + bias > 0))

    def test_extend_inputs_prepends_ones(self):
        w = jnp.zeros((8, 2))
        m = ternary_planes(w, bias_rows=4)
        x = jnp.zeros((3, 8))
        xe = extend_inputs(x, m)
        assert xe.shape == (3, 12)
        np.testing.assert_allclose(np.asarray(xe[:, :4]), 1.0)


class TestCrossbarForward:
    def _setup(self, fan_in=540, n_out=32, seed=0):
        w = ternary_quantize(jax.random.normal(jax.random.PRNGKey(seed),
                                               (fan_in, n_out)))
        x = (jax.random.uniform(jax.random.PRNGKey(seed + 1),
                                (8, fan_in)) > 0.5).astype(jnp.float32)
        return w, x

    def test_ideal_matches_matmul(self):
        w, x = self._setup()
        d = crossbar_forward(jax.random.PRNGKey(2), x, ternary_planes(w),
                             output="diff")
        np.testing.assert_allclose(np.asarray(d),
                                   np.asarray(ideal_ternary_matmul(x, w)),
                                   atol=0.05)

    def test_single_shot_nonlinearity_sign_invariant(self):
        # Sec. IV-B.3: with one-shot accumulation the (monotone)
        # nonlinearity cancels in the differential comparison
        w, x = self._setup()
        ref = ideal_ternary_matmul(x, w)
        d = crossbar_forward(jax.random.PRNGKey(2), x, ternary_planes(w, 32),
                             cfg=NonidealConfig(nonlinearity=True),
                             accumulation="single_shot", output="diff")
        mask = jnp.abs(ref) > 2.0  # away from the fit's junction glitch
        assert float(jnp.mean((d > 0) == (ref > 0), where=mask)) > 0.995

    def test_partial_sum_current_inflated(self):
        # Fig. 8(a): external accumulation of partial sums inflates current
        w, x = self._setup()
        kwargs = dict(cfg=NonidealConfig(nonlinearity=True), output="diff")
        i_ss = crossbar_forward(jax.random.PRNGKey(2), x, ternary_planes(w),
                                accumulation="single_shot", **kwargs)
        # compare accumulated POSITIVE line current via diff vs all-pos weights
        w_pos = jnp.abs(w)
        i_ss_pos = crossbar_forward(jax.random.PRNGKey(2), x,
                                    ternary_planes(w_pos),
                                    accumulation="single_shot", **kwargs)
        i_ps_pos = crossbar_forward(jax.random.PRNGKey(2), x,
                                    ternary_planes(w_pos),
                                    accumulation="partial_sum", **kwargs)
        assert float(jnp.mean(i_ps_pos)) > float(jnp.mean(i_ss_pos)) * 1.1

    def test_device_variation_changes_results_mildly(self):
        w, x = self._setup()
        ref = ideal_ternary_matmul(x, w)
        out = crossbar_forward(jax.random.PRNGKey(7), x, ternary_planes(w, 32),
                               cfg=NonidealConfig(device_variation=True))
        agree = float(jnp.mean((out > 0.5) == (ref > 0)))
        assert 0.6 < agree < 1.0

    def test_binary_output_values(self):
        w, x = self._setup()
        out = crossbar_forward(jax.random.PRNGKey(2), x, ternary_planes(w, 32),
                               cfg=NonidealConfig.all())
        assert set(np.unique(np.asarray(out))) <= {0.0, 1.0}

    def test_deterministic_given_key(self):
        w, x = self._setup()
        a = crossbar_forward(jax.random.PRNGKey(9), x, ternary_planes(w, 32),
                             cfg=NonidealConfig.all())
        b = crossbar_forward(jax.random.PRNGKey(9), x, ternary_planes(w, 32),
                             cfg=NonidealConfig.all())
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestCalibration:
    def _stats(self, n=4000, diff_std=8.0, p_base=20.0, seed=0):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        # near-symmetric current pairs around a LOW common mode (the paper's
        # Table I situation: symmetric conv data, currents near the floor)
        common = p_base + jax.random.uniform(k1, (n,)) * 10.0
        diff = diff_std * jax.random.normal(k2, (n,))
        i_pos = common + 0.5 * diff
        i_neg = common - 0.5 * diff
        return i_pos, i_neg, i_pos + i_neg

    def test_bias_reduces_lower_bound_failures(self):
        i_pos, i_neg, p = self._stats()
        r0 = sa_error_rates(i_pos, i_neg, p, 0.0)
        r32 = sa_error_rates(i_pos, i_neg, p, 32.0)
        assert float(r32["below_lower_bound"]) < float(r0["below_lower_bound"])
        assert float(r0["below_lower_bound"]) > 0.5  # catastrophic w/o bias

    def test_bias_increases_sa_variation_errors(self):
        # Table I: the trade-off direction — bias slightly raises variation errors
        i_pos, i_neg, p = self._stats()
        r0 = sa_error_rates(i_pos, i_neg, p, 0.0)
        r32 = sa_error_rates(i_pos, i_neg, p, 32.0)
        assert float(r32["sensing_variation"]) >= float(r0["sensing_variation"])

    def test_calibrate_picks_nonzero_bias_when_needed(self):
        i_pos, i_neg, p = self._stats()
        best, report = calibrate_bias(i_pos, i_neg, p)
        assert best > 0
        assert report[best]["total"] < report[0]["total"]

    def test_layer_current_stats_shapes(self):
        w = ternary_quantize(jax.random.normal(jax.random.PRNGKey(0), (540, 16)))
        x = (jax.random.uniform(jax.random.PRNGKey(1), (8, 540)) > 0.5
             ).astype(jnp.float32)
        ip, ineg, p = layer_current_stats(jax.random.PRNGKey(2), x,
                                          ternary_planes(w, 0))
        assert ip.shape == ineg.shape == p.shape == (8 * 16,)
        assert bool(jnp.all(p >= 0))


class TestIRCLinear:
    def test_train_eval_shapes_and_grads(self):
        lin = IRCLinear(IRCLinearConfig(fan_in=256, fan_out=8, bias_rows=16))
        params = lin.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 256))
        def loss(p):
            y = lin.apply(p, x, key=jax.random.PRNGKey(2), mode="train",
                          cfg=NonidealConfig.all())
            return jnp.sum(y)
        g = jax.grad(loss)(params)
        assert g["w"].shape == (256, 8)
        assert float(jnp.sum(jnp.abs(g["w"]))) > 0

    def test_eval_tiling_matches_untiled_diff(self):
        # fan_in > macro rows: tiled digital combination == single big matmul
        small_spec = MacroSpec(rows=128, hrs_leak=0.0)
        lin = IRCLinear(IRCLinearConfig(fan_in=300, fan_out=4, bias_rows=8,
                                        output="diff"), spec=small_spec)
        params = lin.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 300))
        d = lin.apply(params, x, key=jax.random.PRNGKey(2), mode="eval")
        w_q = jax.lax.stop_gradient(lin.quantized_weights(params))
        ref = ideal_ternary_matmul((x > 0).astype(jnp.float32), w_q)
        np.testing.assert_allclose(np.asarray(d), np.asarray(ref), atol=1e-3)


class TestMultiTileSensing:
    """Regression: multi-tile layers must NOT silently drop the SA periphery
    (offset, stochastic variation, sensing-range clamp) — each macro's
    front-end applies to its own partial difference before the digital
    combine."""

    def _lin(self, fan_out=6):
        small_spec = MacroSpec(rows=128)
        lin = IRCLinear(IRCLinearConfig(fan_in=300, fan_out=fan_out,
                                        bias_rows=8), spec=small_spec)
        params = lin.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 300))
        return lin, params, x, small_spec

    @pytest.mark.parametrize("cfg", [
        NonidealConfig(sa_variation=True),
        NonidealConfig(sensing_range=True),
        NonidealConfig(sa_variation=True, sensing_range=True)])
    def test_sa_effects_not_dropped(self, cfg):
        lin, params, x, _ = self._lin()
        assert len(lin.map_to_planes(params)) > 1   # actually multi-tile
        key = jax.random.PRNGKey(2)
        out_none = lin.apply(params, x, key=key, mode="eval",
                             cfg=NonidealConfig.none())
        out_cfg = lin.apply(params, x, key=key, mode="eval", cfg=cfg)
        assert not np.array_equal(np.asarray(out_none), np.asarray(out_cfg))

    def test_matches_per_tile_sensed_reference(self):
        """The layer output == per-tile `sensed_diff` outputs combined
        digitally and thresholded (pins the per-tile sensing model)."""
        lin, params, x, spec = self._lin()
        cfg = NonidealConfig.all()
        key = jax.random.PRNGKey(3)
        out = lin.apply(params, x, key=key, mode="eval", cfg=cfg,
                        sa_extra_units=1.0)
        x_bits = (x > 0).astype(jnp.float32)
        total, offset = 0.0, 0
        for t, tile in enumerate(lin.map_to_planes(params)):
            lead = tile.rows - tile.fan_in
            x_t = x_bits[..., offset:offset + tile.rows - lead]
            offset += tile.rows - lead
            total = total + crossbar_forward(
                jax.random.fold_in(key, t), x_t, tile, cfg=cfg, spec=spec,
                sa_extra_units=1.0, output="sensed_diff")
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray((total > 0).astype(jnp.float32)))

    def test_single_tile_sensed_diff_matches_resolve_sa(self):
        """Thresholding one tile's sensed difference at zero reproduces the
        binary SA decisions bit-for-bit (same key discipline)."""
        w = ternary_quantize(jax.random.normal(jax.random.PRNGKey(4),
                                               (200, 12)))
        x = (jax.random.uniform(jax.random.PRNGKey(5), (32, 200)) > 0.5
             ).astype(jnp.float32)
        mapped = ternary_planes(w, bias_rows=16)
        cfg = NonidealConfig.all()
        key = jax.random.PRNGKey(6)
        bits = crossbar_forward(key, x, mapped, cfg=cfg)
        sensed = crossbar_forward(key, x, mapped, cfg=cfg,
                                  output="sensed_diff")
        np.testing.assert_array_equal(
            np.asarray(bits), np.asarray((sensed > 0).astype(jnp.float32)))
