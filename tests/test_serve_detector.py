"""Population-aware detector serving (repro.serve.detector): the stateless
per-request key scheme.  A request's committee draws must be (a) independent
of which requests preceded it or share its wave, and (b) bit-identical to
`run_mc_detector(fold_in(root, request_id), ...)` at the same chip ids —
the engine is a view onto the MC engine, not a second sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import yolo_irc
from repro.core import NonidealConfig
from repro.data.detection import SyntheticDetectionData
from repro.models import IRCDetector
from repro.mc import McConfig, run_mc_detector, detector_planes
from repro.mc.detector_mc import _sampled_chunk_forward
from repro.serve import (DetectorServeEngine, DetectionResponse,
                         ServeQueueFull, PAD_REQUEST_ID)
from repro.train.det_loss import evaluate_map_per_chip

SEED = 11
COMMITTEE = 2
SLOTS = 2


def _detector(scheme="ternary", seed=0):
    cfg = yolo_irc.smoke(scheme)
    det = IRCDetector(cfg)
    params = det.init(jax.random.PRNGKey(seed))
    data = SyntheticDetectionData(cfg.img_hw, cfg.n_classes, cfg.n_anchors,
                                  cfg.strides, seed=seed + 1)
    batch = data.batch_for_step(0, 6)
    params = det.calibrate_bn(params, batch.images)
    return det, params, batch


def _engine(det, params, **kw):
    kw.setdefault("committee", COMMITTEE)
    kw.setdefault("batch_slots", SLOTS)
    kw.setdefault("seed", SEED)
    kw.setdefault("keep_committee", True)
    return DetectorServeEngine(det, params, **kw)


@pytest.fixture(scope="module")
def served():
    """One detector + a 5-request synchronous serve_batch (2 full waves +
    one padded wave), shared by the determinism tests."""
    det, params, batch = _detector()
    eng = _engine(det, params)
    imgs = np.asarray(batch.images)
    responses = eng.serve_batch([imgs[i] for i in range(5)])
    return det, params, batch, eng, responses


class TestStatelessKeys:
    def test_committee_bit_identical_to_chunk_forward(self, served):
        """Every lane — including lanes of padded waves — must equal the MC
        chunk program at key fold_in(root, request_id), chip ids [0..K)."""
        det, params, batch, eng, responses = served
        planes, meta = detector_planes(det, params)
        root = jax.random.PRNGKey(SEED)
        chip_ids = jnp.arange(COMMITTEE, dtype=jnp.uint32)
        imgs = np.asarray(batch.images)
        for r in responses:
            ref = _sampled_chunk_forward(
                params, imgs[r.request_id][None],
                jax.random.fold_in(root, r.request_id), chip_ids, planes,
                det_cfg=det.cfg, spec=det.spec, cfg_ni=NonidealConfig.all(),
                sa_extra=0.0, meta=meta)
            np.testing.assert_array_equal(r.committee, np.asarray(ref[:, 0]))

    def test_committee_bit_identical_to_run_mc_detector(self, served):
        """The serving response's per-chip mAPs ARE run_mc_detector's at the
        same root/request key and chip ids (committee == n_chips)."""
        det, params, batch, eng, responses = served
        rid = 3
        gt_b = [np.asarray(batch.boxes[rid])]
        gt_c = [np.asarray(batch.classes[rid])]
        res = run_mc_detector(
            jax.random.fold_in(jax.random.PRNGKey(SEED), rid), det, params,
            np.asarray(batch.images)[rid][None], gt_b, gt_c,
            mc=McConfig(n_chips=COMMITTEE, chunk_size=COMMITTEE,
                        cfg=NonidealConfig.all()))
        mine = evaluate_map_per_chip(responses[rid].committee[:, None],
                                     gt_b, gt_c, det.cfg.n_anchors,
                                     det.cfg.n_classes)
        np.testing.assert_array_equal(mine, res.per_chip["map50"])

    def test_draws_independent_of_earlier_requests(self, served):
        """Serving a request after DIFFERENT earlier traffic, in a different
        wave composition and slot count, must reproduce its committee
        bit-for-bit — the KEY004 regression for the detector engine."""
        det, params, batch, eng, responses = served
        imgs = np.asarray(batch.images)
        # same rid=3 but as the FIRST request of a fresh engine with
        # different slot count: no shared wave, no preceding requests
        eng2 = _engine(det, params, batch_slots=1)
        (r_alone,) = eng2.serve_batch([imgs[3]])
        assert r_alone.request_id == 0  # ids are engine-local...
        eng3 = _engine(det, params, batch_slots=1)
        eng3.submit(imgs[5], request_id=3)
        eng3.process_pending()
        r3 = eng3.result(3)
        # ...so replay rid=3 explicitly: different image history, different
        # wave partner set, same (root, rid) -> same committee? No: the
        # committee depends on rid only, but eng3 served a different IMAGE
        # under rid 3, so compare the keyed forward instead.
        planes, meta = detector_planes(det, params)
        ref = _sampled_chunk_forward(
            params, imgs[5][None],
            jax.random.fold_in(jax.random.PRNGKey(SEED), 3),
            jnp.arange(COMMITTEE, dtype=jnp.uint32), planes,
            det_cfg=det.cfg, spec=det.spec, cfg_ni=NonidealConfig.all(),
            sa_extra=0.0, meta=meta)
        np.testing.assert_array_equal(r3.committee, np.asarray(ref[:, 0]))
        # and the batch-served rid=3 (wave of 2, after 2 earlier requests)
        # equals a single-slot engine serving the same image as rid=3
        eng4 = _engine(det, params, batch_slots=1)
        eng4.submit(imgs[3], request_id=3)
        eng4.process_pending()
        np.testing.assert_array_equal(eng4.result(3).committee,
                                      responses[3].committee)

    def test_async_scheduler_matches_sync(self, served):
        """The background scheduler thread forms waves by arrival, but the
        stateless keys make every response identical to the sync path."""
        det, params, batch, eng, responses = served
        imgs = np.asarray(batch.images)
        eng2 = _engine(det, params)
        eng2.start()
        try:
            rids = [eng2.submit(imgs[i]) for i in range(5)]
            got = [eng2.result(rid, timeout=600) for rid in rids]
        finally:
            eng2.stop()
        for a, b in zip(got, responses):
            assert a.request_id == b.request_id
            np.testing.assert_array_equal(a.committee, b.committee)
            assert a.confidence == b.confidence


class TestResponses:
    def test_confidence_population_stats(self, served):
        det, params, batch, eng, responses = served
        for r in responses:
            c = r.confidence
            assert c["count"] == COMMITTEE
            assert 0.0 <= c["mean"] <= 1.0 and c["std"] >= 0.0
            assert set(c) >= {"q05", "q25", "q50", "q75", "q95"}
            assert c["q05"] <= c["q50"] <= c["q95"]

    def test_detections_decoded_from_committee_mean(self, served):
        from repro.train.det_loss import decode_detections
        det, params, batch, eng, responses = served
        r = responses[0]
        boxes, scores, classes = decode_detections(
            r.committee.mean(axis=0), det.cfg.n_anchors, det.cfg.n_classes,
            eng.conf_thresh, eng.nms_thresh)
        assert len(r.detections) == len(scores)
        got = np.array([d.score for d in r.detections], np.float32)
        np.testing.assert_array_equal(got, scores.astype(np.float32))

    def test_response_metadata(self, served):
        det, params, batch, eng, responses = served
        assert [r.request_id for r in responses] == list(range(5))
        # 5 requests at 2 slots -> waves of 2/2/1 (last one padded)
        assert [r.wave for r in responses] == [1, 1, 2, 2, 3]
        assert all(r.queue_s > 0 for r in responses)
        lat = eng.stats()["queue_latency"]
        assert lat["count"] == 5 and lat["p50"] <= lat["p95"]


class TestAdmissionControl:
    def test_queue_full_rejects(self, served):
        det, params, batch, eng, _ = served
        img = np.asarray(batch.images)[0]
        eng2 = _engine(det, params, max_queue=2)
        eng2.submit(img)
        eng2.submit(img)
        with pytest.raises(ServeQueueFull):
            eng2.submit(img)
        # draining frees capacity
        assert eng2.process_pending() == 2
        eng2.submit(img)

    def test_request_id_validation(self, served):
        det, params, batch, eng, _ = served
        img = np.asarray(batch.images)[0]
        eng2 = _engine(det, params)
        with pytest.raises(ValueError):
            eng2.submit(img, request_id=PAD_REQUEST_ID)
        with pytest.raises(ValueError):
            eng2.submit(img, request_id=-1)
        eng2.submit(img, request_id=7)
        with pytest.raises(ValueError):      # duplicate in-flight id
            eng2.submit(img, request_id=7)
        eng2.process_pending()
        assert isinstance(eng2.result(7), DetectionResponse)
