"""Detection loss + mAP evaluation tests, and the IRC-mode LM integration
(the paper's technique as a first-class feature on the assigned archs)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.detection import SyntheticDetectionData, ANCHORS
from repro.models import LM
from repro.models.lm_config import IRCMode
from repro.train.det_loss import yolo_loss, evaluate_map, _iou, _nms


class TestYoloLoss:
    def test_loss_finite_and_grad(self):
        d = SyntheticDetectionData(img_hw=(32, 32), stride=8)
        b = d.batch_for_step(0, batch=2)
        pred = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 4, 5 * 8))
        loss, parts = yolo_loss(pred, b.targets, 5, 3)
        assert jnp.isfinite(loss) and float(loss) > 0
        g = jax.grad(lambda p: yolo_loss(p, b.targets, 5, 3)[0])(pred)
        assert float(jnp.sum(jnp.abs(g))) > 0

    def test_perfect_prediction_low_loss(self):
        """Head values constructed FROM the targets give near-zero loss."""
        d = SyntheticDetectionData(img_hw=(32, 32), stride=8)
        b = d.batch_for_step(0, batch=2)
        obj = np.asarray(b.targets["obj"])
        xywh = np.asarray(b.targets["txywh"])
        cls = np.asarray(b.targets["cls"])
        B, gh, gw, A = obj.shape
        pred = np.zeros((B, gh, gw, A, 8), np.float32)
        eps = 1e-4
        txy = np.clip(xywh[..., 0:2], eps, 1 - eps)
        pred[..., 0:2] = np.log(txy / (1 - txy))             # sigmoid^-1
        pred[..., 2:4] = np.log(np.maximum(xywh[..., 2:4], eps)
                                / ANCHORS[:A])
        pred[..., 4] = np.where(obj > 0, 10.0, -10.0)
        for idx in np.argwhere(obj > 0):
            pred[tuple(idx)][5 + cls[tuple(idx)]] = 10.0
        loss, _ = yolo_loss(jnp.asarray(pred.reshape(B, gh, gw, -1)),
                            b.targets, A, 3)
        assert float(loss) < 0.5, float(loss)

    def test_iou_identity(self):
        a = np.array([[0.5, 0.5, 0.2, 0.2]], np.float32)
        assert _iou(a, a)[0, 0] == pytest.approx(1.0)
        b = np.array([[0.9, 0.9, 0.05, 0.05]], np.float32)
        assert _iou(a, b)[0, 0] == pytest.approx(0.0)

    def test_nms_removes_overlaps(self):
        boxes = np.array([[0.5, 0.5, 0.2, 0.2], [0.51, 0.5, 0.2, 0.2],
                          [0.1, 0.1, 0.1, 0.1]], np.float32)
        keep = _nms(boxes, np.array([0.9, 0.8, 0.7]), thresh=0.45)
        assert 0 in keep and 2 in keep and 1 not in keep

    def test_map_perfect_predictions(self):
        """mAP of oracle head values ~ 1."""
        d = SyntheticDetectionData(img_hw=(32, 32), stride=8)
        b = d.batch_for_step(0, batch=4)
        obj = np.asarray(b.targets["obj"])
        xywh = np.asarray(b.targets["txywh"])
        cls = np.asarray(b.targets["cls"])
        B, gh, gw, A = obj.shape
        pred = np.full((B, gh, gw, A, 8), -10.0, np.float32)
        eps = 1e-4
        txy = np.clip(xywh[..., 0:2], eps, 1 - eps)
        pred[..., 0:2] = np.log(txy / (1 - txy))
        pred[..., 2:4] = np.log(np.maximum(xywh[..., 2:4], eps)
                                / ANCHORS[:A])
        pred[..., 4] = np.where(obj > 0, 10.0, -10.0)
        for idx in np.argwhere(obj > 0):
            pred[tuple(idx)][5 + cls[tuple(idx)]] = 10.0
        m = evaluate_map(pred.reshape(B, gh, gw, -1), b.boxes, b.classes,
                         A, 3)
        assert m > 0.85, m

    def test_map_random_predictions_low(self):
        d = SyntheticDetectionData(img_hw=(32, 32), stride=8)
        b = d.batch_for_step(0, batch=4)
        pred = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                            (4, 4, 4, 40)))
        m = evaluate_map(pred, b.boxes, b.classes, 5, 3)
        assert m < 0.4


class TestIRCModeLM:
    """The paper's technique as a first-class LM feature."""

    def test_irc_mode_quantizes_projections(self):
        cfg = get_config("hymba-1.5b", "smoke")
        cfg = dataclasses.replace(cfg, irc=IRCMode(enabled=True))
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                  cfg.vocab_size)
        logits, _ = lm.apply(params, toks, remat="none")
        assert jnp.all(jnp.isfinite(logits))
        # gradient still flows into the latent projections (STE)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        g = jax.grad(lambda p: lm.loss(p, batch)[0])(params)
        wq_grad = g["seg0_hybrid"]["attn"]["wq"]
        assert float(jnp.sum(jnp.abs(wq_grad))) > 0

    def test_irc_training_reduces_loss(self):
        cfg = get_config("phi3-medium-14b", "smoke")
        cfg = dataclasses.replace(cfg, irc=IRCMode(enabled=True))
        lm = LM(cfg)
        from repro.data import SyntheticLMData
        from repro.optim import adamw_init, adamw_update
        data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=32,
                               global_batch=8)
        params = lm.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)

        @jax.jit
        def step(params, opt, batch):
            (l, _), g = jax.value_and_grad(lm.loss, has_aux=True)(params, batch)
            params, opt, _ = adamw_update(g, opt, params, jnp.float32(5e-3))
            return params, opt, l

        losses = []
        for s in range(30):
            params, opt, l = step(params, opt, data.batch_for_step(s))
            losses.append(float(l))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses

    def test_irc_weights_are_ternary_at_use(self):
        cfg = get_config("phi3-medium-14b", "smoke")
        cfg = dataclasses.replace(cfg, irc=IRCMode(enabled=True))
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        q = lm._maybe_irc(params)
        w = np.asarray(q["seg0_dense"]["mlp"]["w_up"])
        assert set(np.unique(w)) <= {-1.0, 0.0, 1.0}
        # embeddings stay digital (paper: first/last layers digital)
        emb = np.asarray(q["embed"])
        assert len(np.unique(emb)) > 3
