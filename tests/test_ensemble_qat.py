"""Ensemble-aware QAT (train/steps.py + mode="train_ensemble"): the
train_chips=1 bit-identity guarantee, the resample_every cadence, the
deviation-plane semantics, and chip-slice invariance to ensemble size."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import yolo_irc
from repro.core import (NonidealConfig, ternary_quantize, ternary_planes,
                        DEFAULT_MACRO)
from repro.data.detection import SyntheticDetectionData
from repro.models import IRCDetector
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.mc import (sample_ensemble, deviation_planes, ensemble_apply,
                      build_train_ensemble)
from repro.train.det_loss import yolo_loss
from repro.train.det_qat import quick_qat
from repro.train.steps import ensemble_key_for_step, make_det_qat_step


def _setup(scheme="ternary"):
    cfg = yolo_irc.smoke(scheme)
    det = IRCDetector(cfg)
    data = SyntheticDetectionData(img_hw=cfg.img_hw, stride=cfg.strides,
                                  n_classes=cfg.n_classes,
                                  n_anchors=cfg.n_anchors)
    return det, data


def _tree_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


class TestTrainChips1BitIdentity:
    def test_step_bit_identical_to_seed_quick_qat(self):
        """The refactored quick_qat (shared step builder, hoisted root key)
        with train_chips=1 must retrace the SEED implementation bit-for-bit:
        same init, same fold_in(PRNGKey(data_seed), s) noise stream, same
        AdamW update."""
        det, data = _setup("ternary")
        steps, batch, lr, wd = 3, 2, 3e-3, 1e-3

        # the seed repo's quick_qat, inlined verbatim
        params = det.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        ocfg = AdamWConfig(weight_decay=wd)

        @jax.jit
        def step(params, opt, images, targets, k):
            def loss_fn(p):
                pred = det.apply(p, images, mode="train", key=k)
                return yolo_loss(pred, targets, det.cfg.n_anchors,
                                 det.cfg.n_classes)
            (loss, _), grads = jax.value_and_grad(loss_fn,
                                                  has_aux=True)(params)
            params, opt, _ = adamw_update(grads, opt, params,
                                          jnp.float32(lr), ocfg)
            return params, opt, loss

        for s in range(steps):
            b = data.batch_for_step(s, batch)
            params, opt, _ = step(params, opt, b.images, b.targets,
                                  jax.random.fold_in(jax.random.PRNGKey(1),
                                                     s))

        new = quick_qat(det, data, steps, batch, lr=lr, weight_decay=wd)
        assert _tree_equal(params, new)

    def test_key_argument_reproduces_data_seed_stream(self):
        """Threading key=PRNGKey(data_seed) must reproduce the default
        stream exactly (the hoisted-root-key satellite fix)."""
        det, data = _setup("ternary")
        a = quick_qat(det, data, 2, 2)                               # data_seed=1
        b = quick_qat(det, data, 2, 2, key=jax.random.PRNGKey(1))
        assert _tree_equal(a, b)


class TestDeviationPlanes:
    def test_deviation_diff_matches_manual_delta(self):
        """ensemble_apply on deviation planes (cfg=none, output='diff') is
        exactly x_ext @ (ep - ep0) - x_ext @ (en - en0) per chip."""
        w = ternary_quantize(jax.random.normal(jax.random.PRNGKey(0),
                                               (90, 12)))
        mapped = ternary_planes(w, bias_rows=8)
        ens = sample_ensemble(jax.random.PRNGKey(1), mapped, 3,
                              cfg=NonidealConfig.all())
        dev = deviation_planes(ens)
        x = (jax.random.uniform(jax.random.PRNGKey(2), (5, 90))
             > 0.5).astype(jnp.float32)
        out = ensemble_apply(dev, x, cfg=NonidealConfig.none(),
                             output="diff")
        leak = DEFAULT_MACRO.hrs_leak
        ep0 = ens.gp + (1 - ens.gp) * leak
        en0 = ens.gn + (1 - ens.gn) * leak
        x_ext = jnp.concatenate([jnp.ones((5, 8)), x], axis=-1)
        want = jnp.stack([x_ext @ (ens.ep[c] - ep0) - x_ext @ (ens.en[c] - en0)
                          for c in range(3)])
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)

    def test_deviation_zero_without_device_variation(self):
        det, _ = _setup("ternary")
        params = det.init(jax.random.PRNGKey(0))
        ens = build_train_ensemble(jax.random.PRNGKey(1), det, params, 2,
                                   cfg=NonidealConfig(sa_variation=True))
        worst = max(float(jnp.max(jnp.abs(g.ep))) + float(jnp.max(jnp.abs(g.en)))
                    for groups in ens.layers.values() for g in groups)
        assert worst == 0.0


class TestTrainEnsembleMode:
    def test_chip_slice_invariant_to_ensemble_size(self):
        """Chip c's train_ensemble output depends only on its chip identity
        (fold_in stream position + per-chip SA key), not on which ensemble
        evaluates it — same invariance the eval-time MC engine pins."""
        det, data = _setup("ternary")
        params = det.init(jax.random.PRNGKey(0))
        b = data.batch_for_step(0, 2)
        ni_all = NonidealConfig.all()
        k_ens, k_step = jax.random.PRNGKey(3), jax.random.PRNGKey(9)
        e3 = build_train_ensemble(k_ens, det, params, 3, cfg=ni_all)
        e1 = build_train_ensemble(k_ens, det, params, 1, cfg=ni_all)
        p3 = det.apply(params, b.images, mode="train_ensemble", key=k_step,
                       cfg_ni=ni_all, ensemble=e3)
        p1 = det.apply(params, b.images, mode="train_ensemble", key=k_step,
                       cfg_ni=ni_all, ensemble=e1)
        assert p3.shape[0] == 3 and p1.shape[0] == 1
        np.testing.assert_array_equal(np.asarray(p3[0]), np.asarray(p1[0]))
        assert not np.array_equal(np.asarray(p3[0]), np.asarray(p3[1]))

    def test_ensemble_step_trains_both_designs(self):
        """One jitted ensemble step updates params with finite values for
        the proposed AND the baseline design (BN path included)."""
        for scheme in ("ternary", "binary"):
            det, data = _setup(scheme)
            params = det.init(jax.random.PRNGKey(0))
            opt = adamw_init(params)
            step = jax.jit(make_det_qat_step(det, train_chips=2,
                                             cfg_ni=NonidealConfig.all()))
            b = data.batch_for_step(0, 2)
            root = jax.random.PRNGKey(1)
            new_params, _, loss = step(params, opt, b.images, b.targets,
                                       jnp.float32(3e-3),
                                       jax.random.fold_in(root, 0),
                                       ensemble_key_for_step(root, 0))
            assert np.isfinite(float(loss)), scheme
            assert not _tree_equal(params, new_params), scheme
            assert all(bool(jnp.all(jnp.isfinite(v)))
                       for v in jax.tree.leaves(new_params)), scheme


class TestResampleCadence:
    def test_key_schedule_windows(self):
        root = jax.random.PRNGKey(7)
        keys = [np.asarray(ensemble_key_for_step(root, s, 3))
                for s in range(7)]
        for s in (1, 2, 4, 5):   # same window -> same population key
            ref = keys[(s // 3) * 3]
            np.testing.assert_array_equal(keys[s], ref)
        assert not np.array_equal(keys[2], keys[3])   # boundary resamples
        assert not np.array_equal(keys[5], keys[6])

    def test_planes_change_exactly_on_schedule(self):
        """With resample_every=2 the sampled population is identical within
        a window and differs across the boundary."""
        det, _ = _setup("ternary")
        params = det.init(jax.random.PRNGKey(0))
        root = jax.random.PRNGKey(5)
        ens = [build_train_ensemble(ensemble_key_for_step(root, s, 2),
                                    det, params, 2, cfg=NonidealConfig.all())
               for s in range(3)]

        def planes(e):
            return np.concatenate([np.asarray(g.ep).ravel()
                                   for gs in e.layers.values() for g in gs])
        np.testing.assert_array_equal(planes(ens[0]), planes(ens[1]))
        assert not np.array_equal(planes(ens[1]), planes(ens[2]))
