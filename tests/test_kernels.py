"""Pallas kernel validation: shape/dtype/effect sweeps against the pure-jnp
oracles (interpret mode on CPU), block-shape sweeps, hypothesis properties,
and bit-exact consistency with the core structural simulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:             # hypothesis optional: property tests skip,
    # example-based tests still run (see requirements-dev.txt)
    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _NoStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NoStrategies()

from repro.core import (DEFAULT_MACRO, NonidealConfig,
                        ternary_quantize, ternary_planes, crossbar_forward)
from repro.kernels import (IrcEpilogueParams, irc_mvm, irc_mvm_ref,
                           ternary_matmul, ternary_matmul_ref,
                           irc_mvm_from_mapped)


def _mk_inputs(B, R, N, seed=0, lrs_frac=0.2, sigma=0.4245):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    gp = (jax.random.uniform(ks[0], (R, N)) < lrs_frac).astype(jnp.float32)
    gn = ((jax.random.uniform(ks[1], (R, N)) < lrs_frac).astype(jnp.float32)
          * (1 - gp))
    vp = jnp.exp(sigma * jax.random.normal(ks[2], (R, N)))
    vn = jnp.exp(sigma * jax.random.normal(ks[3], (R, N)))
    ep = gp * vp + (1 - gp) * 1e-4
    en = gn * vn + (1 - gn) * 1e-4
    x = (jax.random.uniform(ks[4], (B, R)) < 0.5).astype(jnp.float32)
    eps = jax.random.normal(ks[5], (B, N))
    rnd = jax.random.bernoulli(ks[6], 0.5, (B, N)).astype(jnp.float32)
    return x, ep, en, gp, gn, eps, rnd


SHAPES = [(1, 32, 1), (4, 100, 17), (16, 640, 96), (8, 1024, 128),
          (2, 1000, 200), (5, 63, 130)]


class TestIrcMvmKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    def test_matches_ref_all_effects(self, shape):
        B, R, N = shape
        args = _mk_inputs(B, R, N, seed=hash(shape) % 1000)
        params = IrcEpilogueParams()
        out = irc_mvm(*args, params)
        ref = irc_mvm_ref(*args, params)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    @pytest.mark.parametrize("flag", ["apply_nonlinearity", "apply_ir",
                                      "apply_sa", "apply_range"])
    def test_single_effect_toggles(self, flag):
        args = _mk_inputs(8, 320, 64, seed=7)
        base = {f: False for f in ["apply_nonlinearity", "apply_ir",
                                   "apply_sa", "apply_range"]}
        base[flag] = True
        params = IrcEpilogueParams(**base)
        np.testing.assert_array_equal(np.asarray(irc_mvm(*args, params)),
                                      np.asarray(irc_mvm_ref(*args, params)))

    def test_diff_output_close(self):
        args = _mk_inputs(8, 512, 64, seed=3)
        params = IrcEpilogueParams(output="diff")
        out = irc_mvm(*args, params)
        ref = irc_mvm_ref(*args, params)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-4, rtol=1e-5)

    @pytest.mark.parametrize("blocks", [(8, 128, 32), (8, 128, 128),
                                        (16, 256, 256), (8, 256, 512)])
    def test_block_shape_sweep(self, blocks):
        bm, bn, bk = blocks
        args = _mk_inputs(16, 1024, 256, seed=11)
        params = IrcEpilogueParams()
        out = irc_mvm(*args, params, bm=bm, bn=bn, bk=bk)
        ref = irc_mvm_ref(*args, params)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_bf16_planes(self):
        x, ep, en, gp, gn, eps, rnd = _mk_inputs(4, 256, 32, seed=5)
        params = IrcEpilogueParams(apply_sa=False, apply_range=False,
                                   output="diff")
        out = irc_mvm(x, ep.astype(jnp.bfloat16), en.astype(jnp.bfloat16),
                      gp, gn, eps, rnd, params)
        ref = irc_mvm_ref(x, ep.astype(jnp.bfloat16), en.astype(jnp.bfloat16),
                          gp, gn, eps, rnd, params)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-3, rtol=1e-2)

    def test_output_binary_values(self):
        args = _mk_inputs(8, 640, 64, seed=9)
        out = irc_mvm(*args, IrcEpilogueParams())
        assert set(np.unique(np.asarray(out))) <= {0.0, 1.0}

    @settings(max_examples=15, deadline=None)
    @given(B=st.integers(1, 9), R=st.integers(16, 700),
           N=st.integers(1, 150), seed=st.integers(0, 2**16))
    def test_property_kernel_equals_oracle(self, B, R, N, seed):
        args = _mk_inputs(B, R, N, seed=seed)
        params = IrcEpilogueParams()
        np.testing.assert_array_equal(
            np.asarray(irc_mvm(*args, params)),
            np.asarray(irc_mvm_ref(*args, params)))

    def test_consistency_with_core_crossbar(self):
        """Kernel path == repro.core.crossbar_forward given the same key."""
        w = ternary_quantize(jax.random.normal(jax.random.PRNGKey(0), (540, 64)))
        mapped = ternary_planes(w, bias_rows=32)
        x = (jax.random.uniform(jax.random.PRNGKey(1), (8, 540)) > 0.5
             ).astype(jnp.float32)
        cfg = NonidealConfig.all()
        key = jax.random.PRNGKey(42)
        core_out = crossbar_forward(key, x, mapped, cfg=cfg,
                                    spec=DEFAULT_MACRO,
                                    accumulation="single_shot")
        kern_out = irc_mvm_from_mapped(key, x, mapped, cfg, DEFAULT_MACRO)
        assert float(jnp.mean(core_out == kern_out)) > 0.995


class TestTernaryMatmulKernel:
    @pytest.mark.parametrize("shape", [(1, 16, 1), (33, 300, 77),
                                       (128, 512, 128), (200, 1000, 40)])
    def test_matches_ref(self, shape):
        B, K, N = shape
        k1, k2 = jax.random.split(jax.random.PRNGKey(sum(shape)))
        w = jax.random.randint(k1, (K, N), -1, 2, dtype=jnp.int8)
        x = jax.random.normal(k2, (B, K))
        np.testing.assert_allclose(np.asarray(ternary_matmul(x, w)),
                                   np.asarray(ternary_matmul_ref(x, w)),
                                   rtol=1e-6, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        w = jax.random.randint(k1, (256, 64), -1, 2, dtype=jnp.int8)
        x = jax.random.normal(k2, (16, 256)).astype(dtype)
        out = ternary_matmul(x, w)
        ref = ternary_matmul_ref(x, w)
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol * 10)

    @settings(max_examples=10, deadline=None)
    @given(B=st.integers(1, 40), K=st.integers(8, 600), N=st.integers(1, 90),
           seed=st.integers(0, 2**16))
    def test_property_matches_ref(self, B, K, N, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        w = jax.random.randint(k1, (K, N), -1, 2, dtype=jnp.int8)
        x = jax.random.normal(k2, (B, K))
        np.testing.assert_allclose(np.asarray(ternary_matmul(x, w)),
                                   np.asarray(ternary_matmul_ref(x, w)),
                                   rtol=1e-6, atol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("shape", [(2, 64, 16, 16, 16),
                                       (4, 128, 32, 32, 64),
                                       (1, 100, 16, 32, 32),
                                       (2, 256, 64, 128, 128)])
    def test_matches_ref(self, shape):
        from repro.kernels import flash_attention, flash_attention_ref
        H, S, hd, bq, bk = shape
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S), 3)
        q = jax.random.normal(k1, (H, S, hd))
        k = jax.random.normal(k2, (H, S, hd))
        v = jax.random.normal(k3, (H, S, hd))
        out = flash_attention(q, k, v, bq=bq, bk=bk)
        ref = flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=1e-4)

    def test_bf16(self):
        from repro.kernels import flash_attention, flash_attention_ref
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(k1, (2, 128, 32)).astype(jnp.bfloat16)
        k = jax.random.normal(k2, (2, 128, 32)).astype(jnp.bfloat16)
        v = jax.random.normal(k3, (2, 128, 32)).astype(jnp.bfloat16)
        out = flash_attention(q, k, v, bq=64, bk=64)
        ref = flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=3e-2, rtol=3e-2)

    @settings(max_examples=8, deadline=None)
    @given(H=st.integers(1, 4), S=st.sampled_from([32, 64, 96, 160]),
           hd=st.sampled_from([16, 32]), seed=st.integers(0, 2**16))
    def test_property_matches_ref(self, H, S, hd, seed):
        from repro.kernels import flash_attention, flash_attention_ref
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(k1, (H, S, hd))
        k = jax.random.normal(k2, (H, S, hd))
        v = jax.random.normal(k3, (H, S, hd))
        np.testing.assert_allclose(
            np.asarray(flash_attention(q, k, v, bq=32, bk=32)),
            np.asarray(flash_attention_ref(q, k, v)), atol=2e-5, rtol=1e-4)
