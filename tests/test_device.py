"""The repro.device seam: analytic backend pinned bit-identical to the
legacy sampling path, measured-table interpolation semantics, retention
timelines (t=0 is the identity), registry names, and the kernel-path
periphery guard."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import yolo_irc
from repro.core import NonidealConfig, ternary_quantize, ternary_planes
from repro.core import nonideal as ni
from repro.core.crossbar import sample_chip_planes
from repro.core.macro import DEFAULT_MACRO
from repro.data.detection import SyntheticDetectionData
from repro.device import (ANALYTIC_DEVICE, DEVICE_MODELS, DeviceModel,
                          MeasuredDeviceModel, RetentionDrift,
                          default_device, get_device_model)
from repro.mc import McConfig, ensemble_apply_kernel, run_mc_detector
from repro.mc import sample_ensemble
from repro.models import IRCDetector


def _mapped(fan_in=64, n_out=24, bias_rows=8, seed=0):
    w = ternary_quantize(jax.random.normal(jax.random.PRNGKey(seed),
                                           (fan_in, n_out)))
    return ternary_planes(w, bias_rows=bias_rows)


def _legacy_sample_chip_planes(key, g_pos, g_neg, scheme, cfg,
                               spec=DEFAULT_MACRO):
    """The pre-seam sampling math, verbatim — the contract the analytic
    backend must reproduce bit-for-bit."""
    k_var_p, k_var_n, k_sa = jax.random.split(key, 3)
    ep, en = g_pos, g_neg
    if cfg.device_variation:
        ep = g_pos * ni.sample_variation_mask(k_var_p, g_pos.shape,
                                              spec.sigma_lrs)
        if scheme == "binary":
            en = g_neg * ni.sample_variation_mask(k_var_n, (g_neg.shape[0], 1),
                                                  spec.sigma_lrs)
        else:
            en = g_neg * ni.sample_variation_mask(k_var_n, g_neg.shape,
                                                  spec.sigma_lrs)
    if spec.hrs_leak:
        ep = ep + (1.0 - g_pos) * spec.hrs_leak
        en = en + (1.0 - g_neg) * spec.hrs_leak
    return ep, en, k_sa


class TestAnalyticBitIdentity:
    @pytest.mark.parametrize("scheme", ["ternary", "binary"])
    @pytest.mark.parametrize("device", [None, ANALYTIC_DEVICE])
    def test_sample_chip_planes_matches_legacy(self, scheme, device):
        """device=None and device=AnalyticDeviceModel() must reproduce the
        historical sample_chip_planes draw EXACTLY — same split order, same
        mask expressions, same leak constant — or every pinned MC result in
        the repo silently shifts."""
        m = _mapped(seed=3)
        key = jax.random.PRNGKey(42)
        ref = _legacy_sample_chip_planes(key, m.g_pos, m.g_neg, scheme,
                                         NonidealConfig.all())
        got = sample_chip_planes(key, m.g_pos, m.g_neg, scheme,
                                 NonidealConfig.all(), device=device)
        for r, g in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(r), np.asarray(g))

    def test_retention_t0_is_identity(self):
        """RetentionDrift(t_days=0) returns the base draw untouched and
        consumes no extra randomness."""
        m = _mapped(seed=5)
        key = jax.random.PRNGKey(7)
        aged0 = RetentionDrift(base=ANALYTIC_DEVICE, t_days=0.0)
        ref = sample_chip_planes(key, m.g_pos, m.g_neg, "ternary",
                                 NonidealConfig.all())
        got = sample_chip_planes(key, m.g_pos, m.g_neg, "ternary",
                                 NonidealConfig.all(), device=aged0)
        for r, g in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(r), np.asarray(g))

    def test_ensemble_sampling_matches_legacy_per_chip(self):
        """sample_ensemble threads device= into each chip's fold_in draw:
        chip c with the analytic backend == chip c of the legacy path."""
        m = _mapped(seed=1)
        key = jax.random.PRNGKey(9)
        ens_ref = sample_ensemble(key, m, n_chips=4, cfg=NonidealConfig.all())
        ens_dev = sample_ensemble(key, m, n_chips=4, cfg=NonidealConfig.all(),
                                  device=ANALYTIC_DEVICE)
        np.testing.assert_array_equal(np.asarray(ens_ref.ep),
                                      np.asarray(ens_dev.ep))
        np.testing.assert_array_equal(np.asarray(ens_ref.en),
                                      np.asarray(ens_dev.en))
        np.testing.assert_array_equal(np.asarray(ens_ref.sa_keys),
                                      np.asarray(ens_dev.sa_keys))

    @pytest.mark.slow
    def test_run_mc_detector_per_chip_maps_identical(self):
        """End-to-end: the whole-detector MC with device=analytic produces
        the same per-chip mAP stream as device=None."""
        cfg = yolo_irc.smoke("ternary")
        det = IRCDetector(cfg)
        params = det.init(jax.random.PRNGKey(0))
        data = SyntheticDetectionData(img_hw=det.cfg.img_hw,
                                      stride=det.cfg.strides,
                                      n_classes=det.cfg.n_classes,
                                      n_anchors=det.cfg.n_anchors)
        b = data.batch_for_step(1000, 2)
        params = det.calibrate_bn(params, b.images)
        key = jax.random.PRNGKey(13)
        mc = McConfig(n_chips=4, chunk_size=2, cfg=NonidealConfig.all())
        res_none = run_mc_detector(key, det, params, b.images, b.boxes,
                                   b.classes, mc=mc)
        res_dev = run_mc_detector(
            key, det, params, b.images, b.boxes, b.classes,
            mc=dataclasses.replace(mc, device=ANALYTIC_DEVICE))
        np.testing.assert_array_equal(res_none.per_chip["map50"],
                                      res_dev.per_chip["map50"])


class TestMeasuredModel:
    def test_variation_factor_round_trips_grid(self):
        """Interpolation at the tabulated quantiles returns the tabulated
        factors (linear interpolation is exact on its grid)."""
        dev = MeasuredDeviceModel.from_file()
        q = jnp.asarray(dev.var_q, jnp.float32)
        got = np.asarray(dev.variation_factor(q))
        np.testing.assert_allclose(got, np.asarray(dev.var_factor, np.float32),
                                   rtol=1e-6)

    def test_variation_mask_shape_and_positivity(self):
        dev = MeasuredDeviceModel.from_file()
        mask = dev.variation_mask(jax.random.PRNGKey(0), (33, 17))
        assert mask.shape == (33, 17) and mask.dtype == jnp.float32
        arr = np.asarray(mask)
        assert (arr > 0).all()
        # clamped to the measured extremes (jnp.interp tail semantics)
        assert arr.min() >= min(dev.var_factor) - 1e-6
        assert arr.max() <= max(dev.var_factor) + 1e-6

    def test_hrs_leak_from_iv_table(self):
        """The leak is the measured HRS/LRS current ratio at v_read, a
        Python float (it gates trace-time control flow)."""
        dev = MeasuredDeviceModel.from_file()
        leak = dev.hrs_leak_units(DEFAULT_MACRO)
        assert isinstance(leak, float) and 0.0 < leak < 1e-3

    def test_hashable_jit_static(self):
        """Frozen-dataclass backends must hash (they ride through jit as
        static arguments) and compare equal across loads of the same file."""
        a = MeasuredDeviceModel.from_file()
        b = MeasuredDeviceModel.from_file()
        assert hash(a) == hash(b) and a == b


class TestRetentionDrift:
    def test_aged_mask_mean_decays(self):
        """t > 0 lowers the mean LRS current factor (power-law retention)
        and t=0 leaves it exactly at the base draw."""
        key = jax.random.PRNGKey(3)
        shape = (512, 64)
        base = ANALYTIC_DEVICE.variation_mask(key, shape)
        mask0 = RetentionDrift(base=ANALYTIC_DEVICE,
                               t_days=0.0).variation_mask(key, shape)
        np.testing.assert_array_equal(np.asarray(mask0), np.asarray(base))
        m30 = float(jnp.mean(RetentionDrift(base=ANALYTIC_DEVICE, t_days=30.0)
                             .variation_mask(key, shape)))
        m365 = float(jnp.mean(RetentionDrift(base=ANALYTIC_DEVICE,
                                             t_days=365.0)
                              .variation_mask(key, shape)))
        m0 = float(jnp.mean(base))
        assert m30 < m0 and m365 < m30

    def test_base_draw_shared_across_ages(self):
        """Aging is multiplicative on the SAME programming draw — the drift
        term uses a salted key, never the base's — so the day-0/day-N masks
        of one chip are correlated, not independent redraws."""
        key = jax.random.PRNGKey(11)
        shape = (64, 16)
        base = ANALYTIC_DEVICE.variation_mask(key, shape)
        aged = RetentionDrift(base=ANALYTIC_DEVICE,
                              t_days=30.0).variation_mask(key, shape)
        ratio = np.asarray(aged / base)
        # the ratio is the pure drift term: lognormal around the decay
        # median, independent of the base draw's cellwise pattern
        corr = np.corrcoef(np.log(ratio).ravel(),
                           np.log(np.asarray(base)).ravel())[0, 1]
        assert abs(corr) < 0.1
        assert float(np.median(ratio)) < 1.0

    def test_periphery_delegates(self):
        aged = RetentionDrift(base=ANALYTIC_DEVICE, t_days=30.0)
        assert aged.analytic_periphery
        p = jnp.asarray([8.0, 64.0, 300.0])
        np.testing.assert_array_equal(
            np.asarray(aged.sa_offset_sigma(p)),
            np.asarray(ANALYTIC_DEVICE.sa_offset_sigma(p)))


class TestRegistry:
    def test_names(self):
        assert get_device_model("analytic") is ANALYTIC_DEVICE
        assert isinstance(get_device_model("measured"), MeasuredDeviceModel)
        assert set(DEVICE_MODELS) == {"analytic", "measured"}

    def test_t_days_wraps_in_retention(self):
        dev = get_device_model("measured", t_days=30)
        assert isinstance(dev, RetentionDrift)
        assert dev.name == "measured@t30d"
        assert isinstance(dev.base, MeasuredDeviceModel)
        # zero age returns the bare backend, not an identity wrapper
        assert isinstance(get_device_model("measured", t_days=0),
                          MeasuredDeviceModel)

    def test_unknown_name_raises(self):
        with pytest.raises(ValueError, match="unknown device model"):
            get_device_model("spice")

    def test_default_device_resolution(self):
        assert default_device(None) is ANALYTIC_DEVICE
        dev = get_device_model("measured")
        assert default_device(dev) is dev


class TestKernelPeripheryGuard:
    def test_non_analytic_periphery_refused(self):
        """A backend with its own periphery model cannot be expressed in the
        kernel epilogue's scalar params — the kernel path must refuse it
        loudly instead of computing the analytic forms anyway."""

        @dataclasses.dataclass(frozen=True)
        class CustomPeriphery(DeviceModel):
            name = "custom-periphery"

            @property
            def analytic_periphery(self):
                return False

            def variation_mask(self, key, shape, spec=DEFAULT_MACRO):
                return jnp.ones(shape, jnp.float32)

            def hrs_leak_units(self, spec=DEFAULT_MACRO):
                return 0.0

        m = _mapped()
        ens = sample_ensemble(jax.random.PRNGKey(0), m, n_chips=2,
                              cfg=NonidealConfig.all())
        x = jnp.ones((4, m.fan_in), jnp.float32)
        with pytest.raises(NotImplementedError, match="analytic-periphery"):
            ensemble_apply_kernel(ens, x, cfg=NonidealConfig.all(),
                                  device=CustomPeriphery())
