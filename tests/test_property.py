"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
                         "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (DEFAULT_MACRO, NonidealConfig, ternary_quantize,
                        ternary_fractions, ternary_planes, crossbar_forward,
                        ideal_ternary_matmul, ir_drop_factors,
                        nonlinearity_ratio, binary_activation)
from repro.ckpt import save_pytree, restore_pytree


@settings(max_examples=20, deadline=None)
@given(n=st.integers(64, 2048), seed=st.integers(0, 2**16))
def test_ternary_quantize_idempotent_and_regulated(n, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    wt = ternary_quantize(w)
    wt2 = ternary_quantize(wt * 3.0)   # re-quantizing scaled ternary keeps signs
    np.testing.assert_array_equal(np.sign(np.asarray(wt)),
                                  np.sign(np.asarray(wt2)))
    f = np.asarray(ternary_fractions(wt))
    assert abs(f[0] - 0.2) < 0.05 and abs(f[2] - 0.2) < 0.05


@settings(max_examples=15, deadline=None)
@given(fan_in=st.integers(16, 600), n_out=st.integers(1, 40),
       seed=st.integers(0, 2**16))
def test_planes_recover_weights(fan_in, n_out, seed):
    """g_pos - g_neg == ternary weights (mapping is information-preserving)."""
    w = ternary_quantize(jax.random.normal(jax.random.PRNGKey(seed),
                                           (fan_in, n_out)))
    m = ternary_planes(w)
    np.testing.assert_array_equal(np.asarray(m.g_pos - m.g_neg),
                                  np.asarray(w))


@settings(max_examples=10, deadline=None)
@given(fan_in=st.integers(32, 500), n_out=st.integers(1, 24),
       bias=st.integers(0, 32), seed=st.integers(0, 2**16))
def test_bias_rows_never_change_ideal_sign(fan_in, n_out, bias, seed):
    """Common-mode bias rows are differential-invariant (Sec. IV-B.4)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = ternary_quantize(jax.random.normal(k1, (fan_in, n_out)))
    x = (jax.random.uniform(k2, (4, fan_in)) > 0.5).astype(jnp.float32)
    d0 = crossbar_forward(jax.random.PRNGKey(0), x, ternary_planes(w, 0),
                          output="diff")
    db = crossbar_forward(jax.random.PRNGKey(0), x, ternary_planes(w, bias),
                          output="diff")
    np.testing.assert_allclose(np.asarray(d0), np.asarray(db), atol=0.05)


@settings(max_examples=15, deadline=None)
@given(nb=st.integers(1, 32), scale=st.floats(0.0, 40.0),
       seed=st.integers(0, 2**16))
def test_ir_drop_factors_bounded_and_monotone(nb, scale, seed):
    blocks = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed),
                                       (nb,))) * scale
    f = ir_drop_factors(blocks, DEFAULT_MACRO.ir_alpha)
    fa = np.asarray(f)
    assert (fa >= 0).all() and (fa <= 1).all()
    assert (np.diff(fa) <= 1e-6).all()   # farther from driver -> more drop


@settings(max_examples=10, deadline=None)
@given(p=st.floats(0.5, 320.0))
def test_nonlinearity_ratio_positive_bounded(p):
    r = float(nonlinearity_ratio(jnp.array(p)))
    assert 0.0 < r <= 2.5  # fit stays physical on its domain


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_binary_activation_is_binary_and_monotone(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (100,)) * 3
    y = np.asarray(binary_activation(x))
    assert set(np.unique(y)) <= {0.0, 1.0}
    order = np.argsort(np.asarray(x))
    assert (np.diff(y[order]) >= 0).all()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), step=st.integers(0, 10**6))
def test_checkpoint_roundtrip_property(seed, step):
    import tempfile
    k = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(k, (3, 5)),
            "b": {"c": jax.random.normal(k, (7,)).astype(jnp.bfloat16),
                  "d": jnp.asarray(step, jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        save_pytree(tree, d, step=step)
        out = restore_pytree(jax.eval_shape(lambda: tree), d)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_structural_sim_effects_only_flip_small_margins(seed):
    """Under ALL nonideal effects, outputs with LARGE ideal margins are
    stable — the paper's core robustness argument (LLN + single-shot).
    Margin 40 units ≈ 4σ of the accumulated device+SA noise at this fan-in;
    the check needs enough qualifying samples to be a statistic."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = ternary_quantize(jax.random.normal(k1, (540, 32)))
    x = (jax.random.uniform(k2, (64, 540)) > 0.5).astype(jnp.float32)
    ref = ideal_ternary_matmul(x, w)
    out = crossbar_forward(jax.random.PRNGKey(1), x, ternary_planes(w, 32),
                           cfg=NonidealConfig.all())
    big = jnp.abs(ref) > 40.0
    if int(jnp.sum(big)) >= 20:
        agree = float(jnp.mean((out > 0.5) == (ref > 0), where=big))
        assert agree > 0.85, (agree, int(jnp.sum(big)))
