"""Streaming statistics (repro.mc.stats): Welford merge algebra, exact
quantiles, and the stderr that drives the MC convergence monitor."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.mc import (StreamingMoments, welford_add_batch, welford_finalize,
                      welford_init, welford_merge)
from repro.mc.stats import DEFAULT_QUANTILES


def _state(xs):
    return welford_add_batch(welford_init(), jnp.asarray(xs))


class TestWelfordMerge:
    def test_empty_state_is_identity_both_sides(self):
        """merge(init, s) == s == merge(s, init) EXACTLY: with b.count == 0
        the Chan update adds delta*0/safe_n == 0.0 to every field, so the
        identity holds bitwise, not just to tolerance."""
        s = _state(jax.random.normal(jax.random.PRNGKey(0), (37,)))
        for merged in (welford_merge(welford_init(), s),
                       welford_merge(s, welford_init())):
            np.testing.assert_array_equal(np.asarray(merged.count),
                                          np.asarray(s.count))
            np.testing.assert_array_equal(np.asarray(merged.mean),
                                          np.asarray(s.mean))
            np.testing.assert_array_equal(np.asarray(merged.m2),
                                          np.asarray(s.m2))

    def test_merge_of_empties_is_empty(self):
        m = welford_merge(welford_init(), welford_init())
        assert float(m.count) == 0.0 and float(m.mean) == 0.0
        assert float(m.m2) == 0.0

    @pytest.mark.parametrize("sizes", [(5, 7, 11), (1, 1, 100), (64, 1, 3)])
    def test_merge_associative(self, sizes):
        """(a+b)+c == a+(b+c) up to float round-off — what licenses folding
        chunk states in whatever order the engine produces them."""
        keys = jax.random.split(jax.random.PRNGKey(1), 3)
        a, b, c = (_state(3.0 * jax.random.normal(k, (n,)) + 0.5)
                   for k, n in zip(keys, sizes))
        left = welford_finalize(welford_merge(welford_merge(a, b), c))
        right = welford_finalize(welford_merge(a, welford_merge(b, c)))
        assert float(left["count"]) == float(right["count"])
        np.testing.assert_allclose(float(left["mean"]),
                                   float(right["mean"]), atol=1e-6)
        np.testing.assert_allclose(float(left["std"]),
                                   float(right["std"]), atol=1e-5)

    def test_merge_matches_oneshot(self):
        xs = jax.random.normal(jax.random.PRNGKey(2), (200,))
        merged = welford_merge(_state(xs[:73]), _state(xs[73:]))
        fin = welford_finalize(merged)
        np.testing.assert_allclose(float(fin["mean"]), float(jnp.mean(xs)),
                                   atol=1e-6)
        np.testing.assert_allclose(float(fin["std"]), float(jnp.std(xs)),
                                   atol=1e-6)


class TestStreamingMoments:
    def test_quantiles_exact_vs_numpy(self):
        """The retained per-chip scalars make every default quantile EXACTLY
        np.quantile of the full vector, independent of chunking."""
        xs = np.asarray(jax.random.normal(jax.random.PRNGKey(7), (257,)),
                        np.float32)
        sm = StreamingMoments()
        rng = np.random.RandomState(0)
        lo = 0
        while lo < xs.size:           # random ragged chunking
            n = int(rng.randint(1, 40))
            sm.update(jnp.asarray(xs[lo:lo + n]))
            lo += n
        s = sm.summary()
        for q in DEFAULT_QUANTILES:
            expect = float(np.quantile(xs.astype(np.float64), q))
            np.testing.assert_allclose(s[f"q{int(round(q * 100)):02d}"],
                                       expect, rtol=0, atol=1e-12)
        np.testing.assert_array_equal(sm.per_chip, xs)

    def test_stderr_is_population_std_over_sqrt_n(self):
        xs = jax.random.normal(jax.random.PRNGKey(9), (50,))
        sm = StreamingMoments()
        sm.update(xs[:20])
        sm.update(xs[20:])
        expect = float(jnp.std(xs)) / math.sqrt(50)   # ddof=0, like summary()
        np.testing.assert_allclose(sm.stderr(), expect, atol=1e-7)
        assert sm.count == 50.0
        np.testing.assert_allclose(sm.mean_value, float(jnp.mean(xs)),
                                   atol=1e-6)

    def test_stderr_inf_below_two_samples(self):
        sm = StreamingMoments()
        assert sm.stderr() == float("inf")            # empty
        sm.update(jnp.asarray([0.25]))
        assert sm.stderr() == float("inf")            # one sample: no spread
        sm.update(jnp.asarray([0.75]))
        assert math.isfinite(sm.stderr())
