"""Observability layer (repro.obs): run manifests, phase timers, convergence
telemetry — and the two contracts the layer exists for:

  * replaying a run's `metrics.jsonl` chunk events through fresh
    StreamingMoments reproduces the reported population mean±std
    BIT-FOR-BIT (the event stream is evidence, not just a log), and
  * a `stderr_target` early-stopped sweep returns exactly the same moments
    as the same-length PREFIX of the full run (chips are keyed by id, so
    adaptivity is statistically invisible).
"""
import json
import math
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.mc import McConfig, StreamingMoments, run_mc
from repro.obs import (NULL_RUNLOG, ConvergenceMonitor, NullRunLog, PhaseTimer,
                       RunLog, as_runlog, collect_env, maybe_runlog,
                       timed_step)

from test_mc import _layer


# ---------------------------------------------------------------- RunLog


class TestRunLog:
    def test_manifest_events_arrays_roundtrip(self, tmp_path):
        rl = RunLog.create("unit", args={"chips": 4, "arr": jnp.arange(2)},
                           root=str(tmp_path), run_id="r1")
        assert rl.path == tmp_path / "r1"
        man = json.loads((rl.path / "manifest.json").read_text())
        assert man["run_id"] == "r1" and man["status"] == "running"
        assert man["args"]["chips"] == 4
        assert man["args"]["arr"] == [0, 1]          # jax array -> jsonable
        assert man["env"]["jax"] == jax.__version__
        assert man["env"]["backend"] == jax.default_backend()

        rl.log_event("chunk", chips=2, values={"m": np.float32(0.5)})
        rl.log_event("phase", laps=3)
        evs = [json.loads(line) for line in
               (rl.path / "metrics.jsonl").read_text().splitlines()]
        assert [e["kind"] for e in evs] == ["chunk", "phase"]
        assert evs[0]["values"]["m"] == 0.5
        assert evs[0]["t"] >= 0.0

        p = rl.save_array("per_chip_m", jnp.asarray([1.0, 2.0]))
        np.testing.assert_array_equal(np.load(p), [1.0, 2.0])

        rl.finalize(status="ok", best=1.5)
        man = json.loads((rl.path / "manifest.json").read_text())
        assert man["status"] == "ok" and man["summary"]["best"] == 1.5
        assert man["wall_s"] >= 0.0

    def test_default_run_id_unique_and_named(self, tmp_path):
        a = RunLog.create("mc", root=str(tmp_path))
        b = RunLog.create("mc", root=str(tmp_path))
        assert a.path != b.path
        assert "-mc-" in a.path.name

    def test_null_runlog_is_silent(self, tmp_path):
        null = as_runlog(None)
        assert null is NULL_RUNLOG and isinstance(null, NullRunLog)
        assert null.path is None
        null.log_event("chunk", chips=2)
        assert null.save_array("x", np.zeros(2)) is None
        assert null.write_text("a.csv", "x") is None
        assert null.start_trace() is False
        null.finalize(status="ok")
        assert list(tmp_path.iterdir()) == []
        assert as_runlog(NULL_RUNLOG) is NULL_RUNLOG

    def test_maybe_runlog(self, tmp_path):
        assert maybe_runlog(False, "x") is NULL_RUNLOG
        rl = maybe_runlog(True, "x", root=str(tmp_path), run_id="y")
        assert rl.path == tmp_path / "y"

    def test_collect_env_has_toolchain(self):
        env = collect_env()
        for k in ("host", "python", "cpu_count", "jax", "jaxlib", "backend"):
            assert k in env


# ------------------------------------------------------------- PhaseTimer


class TestPhaseTimer:
    def test_first_lap_is_compile_rest_steady(self):
        t = PhaseTimer("p", unit="chips")
        for items in (4, 4, 4):
            with t.lap(items=items):
                pass
        assert t.laps == 3
        assert t.compile_items == 4 and t.steady_items == 8
        assert t.total_s == t.compile_s + t.steady_s
        # steady rate excludes the first lap entirely
        assert t.rate() == 8 / max(t.steady_s, 1e-9)

    def test_single_lap_falls_back_to_total(self):
        t = PhaseTimer("p")
        with t.lap(items=5):
            pass
        assert t.rate() == 5 / max(t.total_s, 1e-9)

    def test_lap_items_settable_inside_block(self):
        t = PhaseTimer("p", unit="tokens")
        with t.lap() as lap:
            lap.items = 17          # only known after the work ran
        assert t.compile_items == 17

    def test_summary_and_log_to(self, tmp_path):
        t = PhaseTimer("decode", unit="tokens")
        with t.lap(items=2):
            pass
        s = t.summary()
        assert s["phase"] == "decode" and s["tokens"] == 2
        rl = RunLog.create("u", root=str(tmp_path), run_id="r")
        t.log_to(rl, extra_field=1)
        ev = json.loads((rl.path / "metrics.jsonl").read_text())
        assert ev["kind"] == "phase" and ev["extra_field"] == 1

    def test_timed_step_wraps_jitted_fn(self):
        t = PhaseTimer("step", unit="steps")
        f = timed_step(jax.jit(lambda x: x * 2), t)
        for i in range(3):
            out = f(jnp.float32(i))
            assert float(out) == 2.0 * i
        assert t.laps == 3 and t.steady_items == 2


# ---------------------------------------------------- ConvergenceMonitor


class TestConvergenceMonitor:
    def test_unknown_metric_rejected(self):
        with pytest.raises(ValueError, match="not a tracked metric"):
            ConvergenceMonitor({"a": StreamingMoments()}, stderr_target=0.1,
                               stderr_metric="b")

    def test_no_target_never_converges_but_logs(self, tmp_path):
        sm = StreamingMoments()
        sm.update(jnp.asarray([0.1, 0.2, 0.3]))
        rl = RunLog.create("u", root=str(tmp_path), run_id="r")
        mon = ConvergenceMonitor({"m": sm}, runlog=rl)
        assert mon.after_chunk(0, 3) is False
        ev = json.loads((rl.path / "metrics.jsonl").read_text())
        assert ev["kind"] == "convergence"
        assert ev["metrics"]["m"]["count"] == 3.0
        assert math.isclose(ev["metrics"]["m"]["stderr"], sm.stderr())

    def test_gating_all_vs_single_metric(self):
        tight = StreamingMoments()
        tight.update(jnp.full((8,), 0.5))             # zero spread
        wide = StreamingMoments()
        wide.update(jnp.asarray([0.0, 1.0, 0.0, 1.0]))
        both = {"tight": tight, "wide": wide}
        assert ConvergenceMonitor(both, stderr_target=0.01).converged() \
            is False                                  # wide blocks ALL-gate
        assert ConvergenceMonitor(both, stderr_target=0.01,
                                  stderr_metric="tight").converged() is True


# ------------------------------------------------------- engine telemetry


def _tiny_run(tmp_path, run_id, **kw):
    from repro.core import ideal_ternary_matmul
    w, mapped, x = _layer(fan_in=64, n_out=16, batch=8, bias_rows=8)
    ref = (ideal_ternary_matmul(x, w) > 0).astype(jnp.float32)
    rl = RunLog.create("mc", root=str(tmp_path), run_id=run_id)
    res = run_mc(jax.random.PRNGKey(42), mapped, x, ref_bits=ref,
                 mc=McConfig(n_chips=8, chunk_size=2), obs=rl, **kw)
    return rl, res


class TestRunMcTelemetry:
    def test_run_emits_events_and_split_timing(self, tmp_path):
        rl, res = _tiny_run(tmp_path, "r1")
        evs = [json.loads(line) for line in
               (rl.path / "metrics.jsonl").read_text().splitlines()]
        kinds = [e["kind"] for e in evs]
        assert kinds[0] == "mc_start" and kinds[-1] == "mc_result"
        assert kinds.count("chunk") == 4 and kinds.count("convergence") == 4
        assert res.n_chips == 8
        assert res.compile_s > 0.0
        assert res.wall_s >= res.compile_s
        assert evs[-1]["compile_s"] == res.compile_s
        assert "steady" in res.summary_line()

    def test_jsonl_replay_reproduces_moments_bitwise(self, tmp_path):
        """The acceptance contract: per-chunk events carry the raw float32
        per-chip values; JSON round-trips them exactly, so refolding the
        stream through fresh StreamingMoments in file order reproduces the
        reported mean/std/quantiles BIT-FOR-BIT (dict equality, no atol)."""
        rl, res = _tiny_run(tmp_path, "r2")
        chunk_evs = [e for e in map(json.loads,
                     (rl.path / "metrics.jsonl").read_text().splitlines())
                     if e["kind"] == "chunk"]
        replay = {name: StreamingMoments()
                  for name in chunk_evs[0]["values"]}
        for ev in chunk_evs:
            for name, vals in ev["values"].items():
                replay[name].update(jnp.asarray(np.asarray(vals, np.float32)))
        assert set(replay) == set(res.metrics)
        for name, sm in replay.items():
            assert sm.summary() == res.metrics[name]
            np.testing.assert_array_equal(sm.per_chip, res.per_chip[name])

    def test_early_stop_equals_full_run_prefix(self, tmp_path):
        """The acceptance contract for adaptivity: with a stderr target the
        sweep stops at a chunk boundary, and its moments/per-chip values are
        EXACTLY the same-length prefix of the full run (chips keyed by id)."""
        _, full = _tiny_run(tmp_path, "full")
        chunk = 2
        vals = full.per_chip["bit_agreement"]

        def prefix_moments(name, n):
            sm = StreamingMoments()
            for lo in range(0, n, chunk):
                sm.update(jnp.asarray(full.per_chip[name][lo:lo + chunk]))
            return sm

        # pick the stderr reached after 2 chunks; the engine must stop at
        # the FIRST chunk boundary at/under it (possibly chunk 1)
        target = prefix_moments("bit_agreement", 4).stderr()
        stop_chunks = next(i for i in range(1, 5)
                           if prefix_moments("bit_agreement",
                                             i * chunk).stderr() <= target)

        rl, early = _tiny_run(tmp_path, "early", stderr_target=target,
                              stderr_metric="bit_agreement")
        assert early.n_chips == stop_chunks * chunk
        assert early.n_chips < full.n_chips
        for name in full.metrics:
            sm = prefix_moments(name, early.n_chips)
            assert early.metrics[name] == sm.summary()
            np.testing.assert_array_equal(early.per_chip[name], sm.per_chip)
        np.testing.assert_array_equal(early.per_chip["bit_agreement"],
                                      vals[:early.n_chips])
        kinds = [json.loads(line)["kind"] for line in
                 (rl.path / "metrics.jsonl").read_text().splitlines()]
        assert "early_stop" in kinds

    def test_no_obs_is_default_and_silent(self, tmp_path, monkeypatch):
        monkeypatch.chdir(tmp_path)
        _, mapped, x = _layer(fan_in=64, n_out=16, batch=8, bias_rows=8)
        res = run_mc(jax.random.PRNGKey(0), mapped, x,
                     mc=McConfig(n_chips=4, chunk_size=2))
        assert res.n_chips == 4
        assert not (tmp_path / "experiments").exists()


# ------------------------------------------------------------ CLI end-to-end


class TestMcCliRunDir:
    def test_layer_cli_emits_run_dir(self, tmp_path, monkeypatch, capsys):
        from repro.launch import mc as mc_cli
        monkeypatch.setattr(sys, "argv", [
            "mc", "--chips", "4", "--chunk", "2", "--batch", "8",
            "--fan-in", "32", "--n-out", "8", "--bias-rows", "4",
            "--ablation", "all", "--run-dir", str(tmp_path / "exp"),
            "--run-id", "cli1"])
        mc_cli.main()
        run = tmp_path / "exp" / "cli1"
        for f in ("manifest.json", "metrics.jsonl", "results.csv",
                  "report.json", "per_chip_bit_agreement_ideal.npy",
                  "per_chip_bit_agreement_all.npy",
                  "per_chip_ones_fraction_all.npy"):
            assert (run / f).exists(), f
        man = json.loads((run / "manifest.json").read_text())
        assert man["status"] == "ok" and man["args"]["chips"] == 4
        assert len(np.load(run / "per_chip_bit_agreement_all.npy")) == 4
        csv = (run / "results.csv").read_text().splitlines()
        assert csv[0].startswith("config,agree_mean")
        assert len(csv) == 3                          # header + ideal + all
        out = capsys.readouterr().out
        assert "run dir:" in out and "compile_s" in out
        report = json.loads((run / "report.json").read_text())
        assert set(report["results"]) == {"ideal", "all"}
        assert report["run_id"] == "cli1"
