"""Sharding rules unit tests + dry-run integration (subprocess, smoke
variant, so the 512-device override never leaks into this process)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import spec_for_axes, cache_axes_tree
from repro.launch.dryrun import collective_bytes, _shape_bytes

REPO = Path(__file__).resolve().parents[1]


def _fake_mesh(shape, names):
    """AbstractMesh stand-in: spec_for_axes only reads axis_names/shape."""
    import numpy as np
    devs = np.empty(shape, object)
    return type("M", (), {"axis_names": names,
                          "devices": type("D", (), {"shape": shape,
                                                    "size": devs.size})()})()


class TestSpecForAxes:
    def setup_method(self):
        self.multi = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
        self.single = _fake_mesh((16, 16), ("data", "model"))

    def test_fsdp_tp_weight(self):
        # (embed, mlp) weight: FSDP over (pod,data), TP over model
        spec = spec_for_axes(("embed", "mlp"), (4096, 16384), self.multi)
        assert spec == P(("pod", "data"), "model")

    def test_divisibility_fixup_drops_axis(self):
        # kv dim 5*64=320 divides 16; 50 does not -> dropped
        assert spec_for_axes(("kv_qkv",), (320,), self.single) == P("model")
        assert spec_for_axes(("kv_qkv",), (50,), self.single) == P(None)

    def test_partial_fsdp_when_only_pod_divides(self):
        # dim 34 divides 2 (pod) but 34/2=17 doesn't divide 16 -> pod only
        spec = spec_for_axes(("embed",), (34,), self.multi)
        assert spec == P("pod")

    def test_no_duplicate_mesh_axis(self):
        # experts take 'model'; the expert-mlp dim must NOT reuse it
        spec = spec_for_axes(("experts", "embed", "mlp"),
                             (128, 4096, 1536), self.multi)
        assert spec == P("model", ("pod", "data"), None)

    def test_missing_axis_on_single_pod(self):
        spec = spec_for_axes(("embed",), (4096,), self.single)
        assert spec == P("data")

    def test_scalar(self):
        assert spec_for_axes((), (), self.single) == P()


class TestCacheAxes:
    def test_kv_cache_axes(self):
        cache = {"k": jax.ShapeDtypeStruct((2, 4, 64, 8, 16), jnp.bfloat16),
                 "v": jax.ShapeDtypeStruct((2, 4, 64, 8, 16), jnp.bfloat16),
                 "index": jax.ShapeDtypeStruct((), jnp.int32)}
        axes = cache_axes_tree(cache)
        assert axes["k"] == ("layers", "act_batch", "act_seq_model", None, None)
        assert axes["index"] == ()


class TestCollectiveParser:
    def test_shape_bytes(self):
        assert _shape_bytes("bf16[128,1024]{1,0}") == 128 * 1024 * 2
        assert _shape_bytes("(f32[8]{0}, f32[16]{0})") == 32 + 64
        assert _shape_bytes("u8[3]") == 3

    def test_collective_bytes(self):
        hlo = """
  %ag = bf16[64,256]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%add
  %rs = f32[32]{0} reduce-scatter(%z)
  %a2a = bf16[16,16]{1,0} all-to-all(%w)
  %cp = f32[8]{0} collective-permute(%v)
  %agst = (f32[4]{0}, f32[4]{0}) all-gather-start(%q)
  %not-a-collective = f32[99]{0} add(%a, %b)
"""
        out = collective_bytes(hlo)
        assert out["all-gather"]["count"] == 2
        assert out["all-gather"]["bytes"] == 64 * 256 * 2 + 32
        assert out["all-reduce"]["bytes"] == 4096
        assert out["reduce-scatter"]["bytes"] == 128
        assert out["all-to-all"]["bytes"] == 512
        assert out["collective-permute"]["bytes"] == 32
        assert out["total_bytes"] == sum(
            out[c]["bytes"] for c in ("all-gather", "all-reduce",
                                      "reduce-scatter", "all-to-all",
                                      "collective-permute"))


@pytest.mark.slow
class TestDryRunIntegration:
    """End-to-end: the dry-run subprocess lowers+compiles smoke cells on the
    512-device multi-pod mesh."""

    @pytest.mark.parametrize("arch,shape", [
        ("phi3-medium-14b", "train_4k"),
        ("qwen3-moe-235b-a22b", "decode_32k"),
    ])
    def test_smoke_cell_compiles(self, tmp_path, arch, shape):
        out = tmp_path / "cell.json"
        env = dict(os.environ)
        env["PYTHONPATH"] = str(REPO / "src")
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", "multi", "--variant", "smoke",
             "--out", str(out)],
            env=env, capture_output=True, text=True, timeout=420)
        assert r.returncode == 0, r.stderr[-2000:]
        rec = json.loads(out.read_text())
        assert rec["status"] == "ok"
        assert rec["devices"] == 512
        assert rec["cost_analysis"].get("flops", 0) > 0
