"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step (grad) + one decode step on CPU; asserts output
shapes and finiteness.  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config, list_archs
from repro.configs.shapes import SHAPES, shape_applicable
from repro.configs import yolo_irc
from repro.core import NonidealConfig
from repro.models import LM, IRCDetector

ARCHS = list_archs()


def _finite(x) -> bool:
    return bool(jnp.all(jnp.isfinite(x)))


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = get_config(arch, "smoke")
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        B, S = 2, 16
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab_size)
        logits, _ = lm.apply(params, toks, remat="none")
        assert logits.shape == (B, S, cfg.vocab_size)
        assert _finite(logits)

        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        loss, metrics = lm.loss(params, batch)
        assert _finite(loss) and float(loss) > 0
        grads = jax.grad(lambda p: lm.loss(p, batch)[0])(params)
        gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert jnp.isfinite(gsum) and gsum > 0
        # one SGD step still produces finite loss
        new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                                  params, grads)
        loss2, _ = lm.loss(new_params, batch)
        assert _finite(loss2)

    def test_decode_step(self, arch):
        cfg = get_config(arch, "smoke")
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        B = 2
        cache = lm.init_cache(B, 32)
        tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0,
                                 cfg.vocab_size)
        for _ in range(3):
            logits, cache = lm.decode_step(params, tok, cache)
            assert logits.shape == (B, 1, cfg.vocab_size)
            assert _finite(logits)
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        assert int(cache["index"]) == 3

    def test_decode_matches_forward(self, arch):
        """Greedy decode logits == teacher-forced forward logits (the KV
        cache / state path computes the same function)."""
        cfg = get_config(arch, "smoke")
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        B, S = 1, 5
        toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                  cfg.vocab_size)
        full_logits, _ = lm.apply(params, toks, remat="none")
        cache = lm.init_cache(B, 16)
        step_logits = []
        for t in range(S):
            lg, cache = lm.decode_step(params, toks[:, t:t + 1], cache)
            step_logits.append(lg[:, 0])
        step_logits = jnp.stack(step_logits, axis=1)
        # local/global masks, caches and scan order must all agree
        assert jnp.allclose(full_logits, step_logits, atol=2e-2), (
            float(jnp.max(jnp.abs(full_logits - step_logits))))

    def test_shape_applicability(self, arch):
        cfg = get_config(arch, "full")
        runnable = {s: shape_applicable(cfg, spec)[0]
                    for s, spec in SHAPES.items()}
        assert runnable["train_4k"] and runnable["prefill_32k"] \
            and runnable["decode_32k"]
        if arch in ("hymba-1.5b", "rwkv6-3b"):
            assert runnable["long_500k"]
        else:
            assert not runnable["long_500k"]

    def test_full_config_exact_assignment(self, arch):
        """The full config carries the exact assigned numbers."""
        cfg = get_config(arch, "full")
        expected = {
            "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
            "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
            "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
            "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
            "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
            "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
            "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
            "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
            "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
            "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        }[arch]
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == expected, (got, expected)


class TestParamCounts:
    """Analytic parameter counts land near the advertised model sizes."""

    @pytest.mark.parametrize("arch,lo,hi", [
        ("hymba-1.5b", 1.0e9, 2.2e9),
        ("phi3-medium-14b", 11e9, 17e9),
        ("deepseek-67b", 60e9, 74e9),
        ("gemma2-27b", 22e9, 32e9),
        ("llama3-405b", 380e9, 430e9),
        ("qwen3-moe-235b-a22b", 200e9, 270e9),
        ("kimi-k2-1t-a32b", 0.85e12, 1.15e12),
        ("musicgen-medium", 1.2e9, 2.2e9),
        ("rwkv6-3b", 2.2e9, 3.6e9),
        ("chameleon-34b", 30e9, 38e9),
    ])
    def test_param_count_band(self, arch, lo, hi):
        cfg = get_config(arch, "full")
        n = cfg.param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B not in [{lo/1e9},{hi/1e9}]B"

    def test_moe_active_counts(self):
        qwen = get_config("qwen3-moe-235b-a22b", "full")
        kimi = get_config("kimi-k2-1t-a32b", "full")
        assert 15e9 <= qwen.active_param_count() <= 30e9     # ~22B active
        assert 25e9 <= kimi.active_param_count() <= 42e9     # ~32B active


class TestDetectorSmoke:
    @pytest.mark.parametrize("scheme", ["ternary", "binary"])
    def test_train_and_eval(self, scheme):
        cfg = yolo_irc.smoke(scheme)
        det = IRCDetector(cfg)
        params = det.init(jax.random.PRNGKey(0))
        img = jax.random.uniform(jax.random.PRNGKey(1), (2, 32, 32, 3))
        out = det.apply(params, img, mode="train", key=jax.random.PRNGKey(2))
        gh = gw = 32 // 8   # stem /2 + 2 pools
        assert out.shape == (2, gh, gw, cfg.n_anchors * (5 + cfg.n_classes))
        assert _finite(out)
        ev = det.apply(params, img, mode="eval", key=jax.random.PRNGKey(3),
                       cfg_ni=NonidealConfig.all())
        assert ev.shape == out.shape and _finite(ev)

    def test_train_eval_consistency_ideal(self):
        """With no nonideal effects, the structural crossbar eval computes
        the same function as the digital train path (up to 0-current ties).
        Eval normalizes the stem with running stats, so calibrate on the
        same batch the train path sees."""
        cfg = yolo_irc.smoke("ternary")
        det = IRCDetector(cfg)
        params = det.init(jax.random.PRNGKey(0))
        img = jax.random.uniform(jax.random.PRNGKey(1), (1, 32, 32, 3))
        params = det.calibrate_bn(params, img)
        tr = det.apply(params, img, mode="train", key=jax.random.PRNGKey(2))
        ev = det.apply(params, img, mode="eval", key=jax.random.PRNGKey(2))
        # head outputs are smooth functions of the binary feature maps;
        # exact agreement of the features implies close head outputs
        rel = float(jnp.max(jnp.abs(tr - ev)) /
                    (jnp.max(jnp.abs(tr)) + 1e-9))
        assert rel < 0.35, rel

    def test_paper_mapping_arithmetic(self):
        """One group channel needs 540 conv cells + bias; with BN the
        baseline needs 540+96=636 <= 1024 rows (paper Sec. IV-A)."""
        from repro.core import DEFAULT_MACRO
        cfg = yolo_irc.baseline()
        fan_in = 3 * 3 * cfg.group
        assert fan_in == 540
        assert fan_in + DEFAULT_MACRO.bn_rows == 636
        rt, ct = DEFAULT_MACRO.macro_grid(fan_in, cfg.group,
                                          DEFAULT_MACRO.bn_rows)
        assert rt == 1   # fits one macro's rows — single-shot is possible
