"""Chip-ensemble Monte Carlo engine (repro.mc): determinism, streaming
statistics, and numerical consistency of the chip-batched paths with the
single-chip structural simulation / kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DEFAULT_MACRO, NonidealConfig, ternary_quantize,
                        ternary_planes, binary_quantize, binary_planes,
                        crossbar_forward, ideal_ternary_matmul)
from repro.kernels import (IrcEpilogueParams, irc_mvm, irc_mvm_chips,
                           irc_mvm_chips_ref, irc_mvm_from_mapped)
from repro.mc import (McConfig, sample_ensemble, calibrate_ensemble_bias,
                      ensemble_apply, ensemble_apply_kernel, run_mc,
                      run_ablation, welford_init, welford_add_batch,
                      welford_finalize, StreamingMoments)


def _layer(fan_in=260, n_out=48, batch=16, bias_rows=16, seed=0,
           scheme="ternary"):
    k_w, k_x = jax.random.split(jax.random.PRNGKey(seed))
    w_lat = jax.random.normal(k_w, (fan_in, n_out))
    if scheme == "ternary":
        w = ternary_quantize(w_lat)
        mapped = ternary_planes(w, bias_rows=bias_rows)
    else:
        w = binary_quantize(w_lat)
        mapped = binary_planes(w)
    x = (jax.random.uniform(k_x, (batch, fan_in)) > 0.5).astype(jnp.float32)
    return w, mapped, x


class TestWelford:
    @pytest.mark.parametrize("chunks", [[512], [128, 128, 128, 128],
                                        [1, 7, 100, 404], [500, 12]])
    def test_chunked_matches_oneshot(self, chunks):
        xs = jax.random.uniform(jax.random.PRNGKey(3), (sum(chunks),))
        state = welford_init()
        lo = 0
        for n in chunks:
            state = welford_add_batch(state, xs[lo:lo + n])
            lo += n
        fin = welford_finalize(state)
        np.testing.assert_allclose(float(fin["mean"]), float(jnp.mean(xs)),
                                   atol=1e-6)
        np.testing.assert_allclose(float(fin["std"]), float(jnp.std(xs)),
                                   atol=1e-6)
        assert float(fin["count"]) == sum(chunks)

    def test_streaming_moments_quantiles(self):
        xs = jax.random.normal(jax.random.PRNGKey(5), (300,))
        sm = StreamingMoments()
        for lo in range(0, 300, 64):
            sm.update(xs[lo:lo + 64])
        s = sm.summary()
        np.testing.assert_allclose(s["mean"], float(jnp.mean(xs)), atol=1e-6)
        np.testing.assert_allclose(
            s["q50"], float(np.quantile(np.asarray(xs), 0.5)), atol=1e-6)
        assert s["q05"] <= s["q25"] <= s["q50"] <= s["q75"] <= s["q95"]


class TestEnsembleDeterminism:
    def test_same_key_same_ensemble(self):
        _, mapped, _ = _layer()
        key = jax.random.PRNGKey(11)
        e1 = sample_ensemble(key, mapped, 8)
        e2 = sample_ensemble(key, mapped, 8)
        for a, b in zip(jax.tree.leaves(e1), jax.tree.leaves(e2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_different_key_distinct_chips(self):
        _, mapped, _ = _layer()
        e1 = sample_ensemble(jax.random.PRNGKey(11), mapped, 4)
        e2 = sample_ensemble(jax.random.PRNGKey(12), mapped, 4)
        assert float(jnp.max(jnp.abs(e1.ep - e2.ep))) > 0.0

    def test_chips_within_ensemble_distinct(self):
        _, mapped, _ = _layer()
        ens = sample_ensemble(jax.random.PRNGKey(0), mapped, 4)
        assert float(jnp.max(jnp.abs(ens.ep[0] - ens.ep[1]))) > 0.0

    def test_same_key_same_statistics(self):
        w, mapped, x = _layer()
        ref = (ideal_ternary_matmul(x, w) > 0).astype(jnp.float32)
        mc = McConfig(n_chips=8, chunk_size=4)
        key = jax.random.PRNGKey(2)
        r1 = run_mc(key, mapped, x, ref_bits=ref, mc=mc)
        r2 = run_mc(key, mapped, x, ref_bits=ref, mc=mc)
        assert r1.metrics["bit_agreement"] == r2.metrics["bit_agreement"]
        np.testing.assert_array_equal(r1.per_chip["bit_agreement"],
                                      r2.per_chip["bit_agreement"])
        r3 = run_mc(jax.random.PRNGKey(3), mapped, x, ref_bits=ref, mc=mc)
        assert (r1.metrics["bit_agreement"]["mean"]
                != r3.metrics["bit_agreement"]["mean"])

    def test_chunking_invisible(self):
        """Chip c is keyed by fold_in(key, c) regardless of chunk layout."""
        w, mapped, x = _layer()
        ref = (ideal_ternary_matmul(x, w) > 0).astype(jnp.float32)
        key = jax.random.PRNGKey(4)
        r_small = run_mc(key, mapped, x, ref_bits=ref,
                         mc=McConfig(n_chips=12, chunk_size=5))
        r_big = run_mc(key, mapped, x, ref_bits=ref,
                       mc=McConfig(n_chips=12, chunk_size=12))
        np.testing.assert_array_equal(r_small.per_chip["bit_agreement"],
                                      r_big.per_chip["bit_agreement"])
        np.testing.assert_allclose(r_small.metrics["bit_agreement"]["mean"],
                                   r_big.metrics["bit_agreement"]["mean"],
                                   atol=1e-6)


class TestEnsembleConsistency:
    @pytest.mark.parametrize("scheme,accumulation",
                             [("ternary", "single_shot"),
                              ("ternary", "partial_sum"),
                              ("binary", "single_shot")])
    def test_matches_single_chip_loop(self, scheme, accumulation):
        """Ensemble chip c == crossbar_forward(fold_in(key, c)) bit-for-bit."""
        _, mapped, x = _layer(scheme=scheme)
        cfg = NonidealConfig.all()
        key = jax.random.PRNGKey(21)
        ens = sample_ensemble(key, mapped, 5, cfg=cfg)
        out = ensemble_apply(ens, x, cfg=cfg, accumulation=accumulation,
                             partial_rows=212)
        for c in range(5):
            ref = crossbar_forward(jax.random.fold_in(key, c), x, mapped,
                                   cfg=cfg, accumulation=accumulation,
                                   partial_rows=212)
            np.testing.assert_array_equal(np.asarray(out[c]), np.asarray(ref))

    @pytest.mark.parametrize("output", ["binary", "diff", "sensed_diff"])
    def test_output_modes_match_single_chip(self, output):
        """Every output mode forwards through BOTH the hoisted shared-planes
        branch and the per-chip-x branch consistently with crossbar_forward:
        SA decisions bit-for-bit; analog readouts up to the round-off of
        batched-vs-unbatched einsum lowering (the stochastic terms — offset
        draws, range-failure signs — are PRNG-exact either way)."""
        _, mapped, x = _layer(fan_in=96, n_out=16, batch=8)
        cfg = NonidealConfig.all()
        key = jax.random.PRNGKey(31)
        ens = sample_ensemble(key, mapped, 3, cfg=cfg)
        shared = ensemble_apply(ens, x, cfg=cfg, output=output)
        per_chip = ensemble_apply(
            ens, jnp.broadcast_to(x, (3,) + x.shape), cfg=cfg, output=output,
            per_chip_x=True)
        for c in range(3):
            ref = crossbar_forward(jax.random.fold_in(key, c), x, mapped,
                                   cfg=cfg, output=output)
            for out in (shared[c], per_chip[c]):
                if output == "binary":
                    np.testing.assert_array_equal(np.asarray(out),
                                                  np.asarray(ref))
                else:
                    np.testing.assert_allclose(np.asarray(out),
                                               np.asarray(ref), atol=1e-4)

    def test_kernel_backend_matches_single_kernel_loop(self):
        _, mapped, x = _layer(batch=8)
        cfg = NonidealConfig.all()
        key = jax.random.PRNGKey(23)
        ens = sample_ensemble(key, mapped, 3, cfg=cfg)
        out = ensemble_apply_kernel(ens, x, cfg=cfg)
        for c in range(3):
            ref = irc_mvm_from_mapped(jax.random.fold_in(key, c), x, mapped,
                                      cfg, DEFAULT_MACRO)
            np.testing.assert_array_equal(np.asarray(out[c]), np.asarray(ref))

    def test_calibrated_ensemble_runs(self):
        w, mapped, x = _layer(bias_rows=32)
        ens = sample_ensemble(jax.random.PRNGKey(1), mapped, 3)
        cal = calibrate_ensemble_bias(ens, x)
        assert cal.bias_units.shape == (3,)
        assert cal.planes_per_chip()
        assert float(jnp.max(cal.bias_units)) <= 32
        out = ensemble_apply(cal, x, cfg=NonidealConfig.all())
        assert out.shape == (3,) + (x.shape[0], mapped.n_out)
        # deactivated bias rows carry no LRS count on either plane
        lead = cal.lead_rows
        counts = np.asarray(jnp.sum(cal.gp[:, :lead, 0], axis=1))
        np.testing.assert_array_equal(counts, np.asarray(cal.bias_units))


class TestChipBatchedKernel:
    @pytest.mark.parametrize("shape", [(3, 4, 100, 17), (2, 8, 320, 64),
                                       (4, 2, 63, 130)])
    def test_matches_vmapped_ref(self, shape):
        C, B, R, N = shape
        ks = jax.random.split(jax.random.PRNGKey(sum(shape)), 8)
        gp = (jax.random.uniform(ks[0], (C, R, N)) < 0.2).astype(jnp.float32)
        gn = ((jax.random.uniform(ks[1], (C, R, N)) < 0.2).astype(jnp.float32)
              * (1 - gp))
        ep = gp * jnp.exp(0.42 * jax.random.normal(ks[2], (C, R, N))) \
            + (1 - gp) * 1e-4
        en = gn * jnp.exp(0.42 * jax.random.normal(ks[3], (C, R, N))) \
            + (1 - gn) * 1e-4
        x = (jax.random.uniform(ks[4], (B, R)) < 0.5).astype(jnp.float32)
        eps = jax.random.normal(ks[5], (C, B, N))
        rnd = jax.random.bernoulli(ks[6], 0.5, (C, B, N)).astype(jnp.float32)
        params = IrcEpilogueParams()
        out = irc_mvm_chips(x, ep, en, gp, gn, eps, rnd, params)
        ref = irc_mvm_chips_ref(x, ep, en, gp, gn, eps, rnd, params)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        # chip c of the batched launch == a single-chip kernel call
        for c in range(C):
            sc = irc_mvm(x, ep[c], en[c], gp[c], gn[c], eps[c], rnd[c], params)
            np.testing.assert_array_equal(np.asarray(out[c]), np.asarray(sc))
        # shared [R, N] placement planes (one HBM copy for all chips) give
        # the same result as explicitly per-chip copies
        gp0 = jnp.broadcast_to(gp[0], (C,) + gp.shape[1:])
        gn0 = jnp.broadcast_to(gn[0], (C,) + gn.shape[1:])
        shared = irc_mvm_chips(x, ep, en, gp[0], gn[0], eps, rnd, params)
        full = irc_mvm_chips(x, ep, en, gp0, gn0, eps, rnd, params)
        np.testing.assert_array_equal(np.asarray(shared), np.asarray(full))
        ref_sh = irc_mvm_chips_ref(x, ep, en, gp[0], gn[0], eps, rnd, params)
        np.testing.assert_array_equal(np.asarray(shared), np.asarray(ref_sh))


class TestRunMc:
    def test_64_chips_all_effects_single_jitted_call(self):
        """Acceptance: >= 64 chips, all effects, one jitted computation,
        mean/std/quantile statistics out."""
        w, mapped, x = _layer(fan_in=128, n_out=32, batch=16)
        ref = (ideal_ternary_matmul(x, w) > 0).astype(jnp.float32)
        cfg = NonidealConfig.all()
        key = jax.random.PRNGKey(0)
        ens = sample_ensemble(key, mapped, 64, cfg=cfg)
        out = ensemble_apply(ens, x, cfg=cfg)     # one jitted call, 64 chips
        assert out.shape == (64, 16, 32)
        res = run_mc(key, mapped, x, ref_bits=ref,
                     mc=McConfig(n_chips=64, chunk_size=64, cfg=cfg))
        m = res.metrics["bit_agreement"]
        assert 0.0 < m["mean"] <= 1.0 and m["std"] > 0.0
        assert m["q05"] <= m["q50"] <= m["q95"]
        assert res.per_chip["bit_agreement"].shape == (64,)
        # the chunked streaming mean equals the one-shot jnp mean
        per_chip = jnp.mean(
            (out > 0.5).astype(jnp.float32) == ref, axis=(1, 2))
        np.testing.assert_allclose(m["mean"], float(jnp.mean(per_chip)),
                                   atol=1e-6)
        np.testing.assert_allclose(m["std"], float(jnp.std(per_chip)),
                                   atol=1e-6)

    def test_ablation_sweep_orders_effects(self):
        w, mapped, x = _layer(fan_in=128, n_out=32, batch=16)
        ref = (ideal_ternary_matmul(x, w) > 0).astype(jnp.float32)
        res = run_ablation(jax.random.PRNGKey(1), mapped, x, ref_bits=ref,
                           mc=McConfig(n_chips=8, chunk_size=8))
        agree = {k: v.metrics["bit_agreement"]["mean"]
                 for k, v in res.items()}
        assert agree["ideal"] >= agree["devvar"] >= agree["all"] - 1e-6

    def test_host_metric_callback_streams_per_chunk(self):
        """Host-side callbacks (e.g. evaluate_map — not an array program)
        see each chunk's outputs on the host and fold into the same
        streaming accumulators as on-device metrics."""
        w, mapped, x = _layer(fan_in=96, n_out=16, batch=8)
        ref = (ideal_ternary_matmul(x, w) > 0).astype(jnp.float32)
        shapes = []

        def host_ones(out_np):
            shapes.append(out_np.shape)
            return out_np.mean(axis=(1, 2))

        res = run_mc(jax.random.PRNGKey(5), mapped, x, ref_bits=ref,
                     mc=McConfig(n_chips=6, chunk_size=3),
                     host_metric_fns={"host_ones": host_ones})
        assert shapes == [(3, 8, 16), (3, 8, 16)]
        np.testing.assert_allclose(res.per_chip["host_ones"],
                                   res.per_chip["ones_fraction"], atol=1e-6)
        m = res.metrics["host_ones"]
        assert m["count"] == 6.0 and "q50" in m

    def test_sharded_run_matches_unsharded(self):
        from repro.launch.mesh import make_host_mesh
        w, mapped, x = _layer(fan_in=96, n_out=16, batch=8)
        ref = (ideal_ternary_matmul(x, w) > 0).astype(jnp.float32)
        key = jax.random.PRNGKey(9)
        mc = McConfig(n_chips=4, chunk_size=4)
        r0 = run_mc(key, mapped, x, ref_bits=ref, mc=mc)
        r1 = run_mc(key, mapped, x, ref_bits=ref, mc=mc,
                    mesh=make_host_mesh())
        np.testing.assert_array_equal(r0.per_chip["bit_agreement"],
                                      r1.per_chip["bit_agreement"])
