"""Kernel-routed detector ensemble (detector.apply use_kernel=...) and the
block-shape autotuner behind the auto dispatch.

The contract pinned here is the one the dispatch relies on: with the chips
lowered onto the fused Pallas kernel (`ensemble_apply_kernel`, interpret
mode on CPU) the detector's ensemble outputs are BIT-IDENTICAL to the
kernel's jnp oracle (`kernel_impl="ref"`) through the full network — eval
mode (binary SA decisions, chip-shared first layer AND chip-diverged
per-chip downstream layers) and the train-ensemble deviation path alike.
Against the default vmapped-jnp reference path the binary eval outputs must
agree on essentially every SA decision (the analog pre-activations differ
only by float re-association in the fused epilogue).

Autotune side: absent table entries must keep problems on the reference
path (never a silent slow kernel), committed winners must round-trip
through the lru-cached table, and forcing the kernel outside its
single-shot envelope must raise, not silently fall back."""
import json
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import yolo_irc
from repro.core import NonidealConfig
from repro.kernels import autotune
from repro.models import IRCDetector
from repro.mc import build_detector_ensemble, build_train_ensemble


def _detector(scheme="ternary", seed=0):
    cfg = yolo_irc.smoke(scheme)
    det = IRCDetector(cfg)
    params = det.init(jax.random.PRNGKey(seed))
    calib = jax.random.uniform(jax.random.PRNGKey(seed + 1), (4, 32, 32, 3))
    return det, det.calibrate_bn(params, calib)


class TestKernelRoutedDetector:
    def test_eval_pallas_bit_exact_vs_kernel_oracle(self):
        """Full-network ensemble eval with the Pallas kernel on every group
        matmul == the same routing with the kernel's jnp oracle, bit-for-bit
        (covers chip-shared x in the first IRC layer and per-chip x in every
        downstream layer)."""
        det, params = _detector("ternary")
        imgs = jax.random.uniform(jax.random.PRNGKey(2), (2, 32, 32, 3))
        ni = NonidealConfig.all()
        ens = build_detector_ensemble(jax.random.PRNGKey(3), det, params, 2,
                                      cfg=ni)
        out_k = det.apply(params, imgs, mode="ensemble", ensemble=ens,
                          cfg_ni=ni, use_kernel=True, kernel_impl="pallas")
        out_r = det.apply(params, imgs, mode="ensemble", ensemble=ens,
                          cfg_ni=ni, use_kernel=True, kernel_impl="ref")
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    def test_eval_routed_agrees_with_reference_path(self):
        """Kernel-routed binary eval vs the default vmapped-jnp path: the SA
        decisions agree on >= 99% of units (float re-association in the
        fused epilogue may flip near-threshold units, nothing more)."""
        det, params = _detector("ternary")
        imgs = jax.random.uniform(jax.random.PRNGKey(2), (2, 32, 32, 3))
        ni = NonidealConfig.all()
        ens = build_detector_ensemble(jax.random.PRNGKey(3), det, params, 2,
                                      cfg=ni)
        out_k = det.apply(params, imgs, mode="ensemble", ensemble=ens,
                          cfg_ni=ni, use_kernel=True)
        out_j = det.apply(params, imgs, mode="ensemble", ensemble=ens,
                          cfg_ni=ni, use_kernel=False)
        assert out_k.shape == out_j.shape
        frac = float(np.mean(np.asarray(out_k) == np.asarray(out_j)))
        assert frac >= 0.99, frac

    def test_train_ensemble_pallas_bit_exact_vs_kernel_oracle(self):
        """The deviation (output="diff") path through the kernel: pallas ==
        jnp oracle bit-for-bit, and both match the reference train-ensemble
        path to float tolerance."""
        det, params = _detector("ternary")
        imgs = jax.random.uniform(jax.random.PRNGKey(2), (2, 32, 32, 3))
        ni = NonidealConfig.all()
        ens = build_train_ensemble(jax.random.PRNGKey(4), det, params, 2,
                                   cfg=ni)
        key = jax.random.PRNGKey(5)
        out_k = det.apply(params, imgs, mode="train_ensemble", key=key,
                          cfg_ni=ni, ensemble=ens, use_kernel=True,
                          kernel_impl="pallas")
        out_r = det.apply(params, imgs, mode="train_ensemble", key=key,
                          cfg_ni=ni, ensemble=ens, use_kernel=True,
                          kernel_impl="ref")
        out_j = det.apply(params, imgs, mode="train_ensemble", key=key,
                          cfg_ni=ni, ensemble=ens, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_j),
                                   atol=1e-4, rtol=1e-4)

    def test_forced_kernel_outside_single_shot_raises(self):
        """The kernel's fused epilogue is single-shot only; forcing it on
        the binary (partial-sum) design must raise, not silently fall
        back."""
        det, params = _detector("binary")
        imgs = jax.random.uniform(jax.random.PRNGKey(2), (2, 32, 32, 3))
        ni = NonidealConfig.all()
        ens = build_detector_ensemble(jax.random.PRNGKey(3), det, params, 2,
                                      cfg=ni)
        with pytest.raises(ValueError, match="single_shot"):
            det.apply(params, imgs, mode="ensemble", ensemble=ens,
                      cfg_ni=ni, use_kernel=True)

    def test_auto_dispatch_matches_reference_path(self):
        """use_kernel=None consults the committed tuning table; whatever it
        routes to must reproduce the reference path's decisions (on CPU the
        committed table keeps everything on the jnp path, so this is
        bit-exact; on a backend with kernel wins it's the >=99% contract
        above)."""
        det, params = _detector("ternary")
        imgs = jax.random.uniform(jax.random.PRNGKey(2), (2, 32, 32, 3))
        ni = NonidealConfig.all()
        ens = build_detector_ensemble(jax.random.PRNGKey(3), det, params, 2,
                                      cfg=ni)
        out_a = det.apply(params, imgs, mode="ensemble", ensemble=ens,
                          cfg_ni=ni)                      # auto
        out_j = det.apply(params, imgs, mode="ensemble", ensemble=ens,
                          cfg_ni=ni, use_kernel=False)    # forced reference
        frac = float(np.mean(np.asarray(out_a) == np.asarray(out_j)))
        assert frac >= 0.99, frac


class TestAutotuneTable:
    @pytest.fixture(autouse=True)
    def _fresh_table(self, monkeypatch, tmp_path):
        """Point the module at a throwaway tuning.json and drop the lru
        cache around every test (the committed table must not leak in)."""
        monkeypatch.setattr(autotune, "TUNING_JSON",
                            tmp_path / "tuning.json")
        autotune.load_table.cache_clear()
        yield
        autotune.load_table.cache_clear()

    def test_absent_entry_stays_on_reference_path(self):
        assert autotune.lookup(8, 128, 60, 556) is None
        assert autotune.kernel_wins(8, 128, 60, 556) is False
        assert autotune.best_blocks(8, 128, 60, 556) \
            == autotune.DEFAULT_BLOCKS

    def test_committed_winner_round_trips(self):
        key = autotune.problem_key(4, 64, 60, 556)
        autotune.TUNING_JSON.write_text(json.dumps({
            key: {"bm": 16, "bn": 128, "bk": 256, "use_kernel": True,
                  "kernel_us": 10.0, "ref_us": 20.0}}))
        autotune.load_table.cache_clear()
        assert autotune.kernel_wins(4, 64, 60, 556) is True
        assert autotune.best_blocks(4, 64, 60, 556) == (16, 128, 256)
        # losing entries keep their measured blocks but never dispatch
        autotune.TUNING_JSON.write_text(json.dumps({
            key: {"bm": 16, "bn": 128, "bk": 256, "use_kernel": False,
                  "kernel_us": 20.0, "ref_us": 10.0}}))
        autotune.load_table.cache_clear()
        assert autotune.kernel_wins(4, 64, 60, 556) is False

    def test_problem_key_is_backend_scoped(self):
        assert autotune.problem_key(8, 128, 60, 556, backend="tpu") \
            == "tpu/c8_m128_n60_k556"
        # default backend is this process's jax backend
        assert autotune.problem_key(8, 128, 60, 556).startswith(
            jax.default_backend() + "/")

    def test_detector_problems_cover_all_stage_geometries(self):
        cfg = yolo_irc.smoke("ternary")
        probs = autotune.detector_problems(cfg, batch=2, chips=8)
        K = cfg.bias_rows + 9 * cfg.group
        H = cfg.img_hw[0] // 2
        assert (8, 2 * H * H, cfg.group, K) in probs
        assert all(c == 8 and n == cfg.group and k == K
                   for c, _, n, k in probs)
        assert len(probs) == len(set(probs))

    def test_committed_table_matches_schema(self):
        """The ACTUAL committed tuning.json (the one dispatch reads in
        production) parses and carries the dispatch fields."""
        committed = Path(autotune.__file__).with_name("tuning.json")
        table = json.loads(committed.read_text())
        assert table, "committed tuning.json is empty"
        for key, rec in table.items():
            assert "/" in key
            for field in ("bm", "bn", "bk", "use_kernel", "kernel_us",
                          "ref_us"):
                assert field in rec, (key, field)
