"""Tests for `repro.analysis`: per-rule good/bad fixtures, baseline
semantics (exit codes of `python -m repro.analysis`), shape-contract
catching, and the runtime guards the passes are paired with."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (Finding, assert_clean_subtrees, load_baseline,
                            split_by_baseline, write_baseline)
from repro.analysis.keys import run_key_pass
from repro.analysis.trace import run_trace_pass

REPO = Path(__file__).resolve().parents[1]


def keys(src: str):
    return run_key_pass("fixture.py", textwrap.dedent(src))


def trace(src: str, roots=None):
    return run_trace_pass("fixture.py", textwrap.dedent(src), roots)


def rules(findings):
    return [f.rule for f in findings]


# --------------------------------------------------------------- KEY rules

class TestKeyDiscipline:
    def test_key001_double_consumption(self):
        out = keys("""
            import jax
            def f(key, shape):
                a = jax.random.normal(key, shape)
                b = jax.random.uniform(key, shape)
                return a + b
        """)
        assert rules(out) == ["KEY001"]

    def test_key001_clean_after_split(self):
        assert keys("""
            import jax
            def f(key, shape):
                k1, k2 = jax.random.split(key)
                return jax.random.normal(k1, shape) + \\
                    jax.random.uniform(k2, shape)
        """) == []

    def test_key001_rebinding_resets(self):
        assert keys("""
            import jax
            def f(key, shape):
                a = jax.random.normal(key, shape)
                key = jax.random.fold_in(key, 1)
                return a + jax.random.normal(key, shape)
        """) == []

    def test_key001_exclusive_branches_not_flagged(self):
        assert keys("""
            import jax
            def f(key, shape, flag):
                if flag:
                    return jax.random.normal(key, shape)
                else:
                    return jax.random.uniform(key, shape)
        """) == []

    def test_key001_loop_reuse(self):
        out = keys("""
            import jax
            def f(key, xs):
                acc = []
                for x in xs:
                    acc.append(jax.random.normal(key, x.shape))
                return acc
        """)
        assert rules(out) == ["KEY001"]
        assert "every iteration replays" in out[0].message

    def test_key001_loop_fold_in_clean(self):
        assert keys("""
            import jax
            def f(key, xs):
                acc = []
                for i, x in enumerate(xs):
                    k = jax.random.fold_in(key, i)
                    acc.append(jax.random.normal(k, x.shape))
                return acc
        """) == []

    def test_key001_sees_through_import_alias(self):
        out = keys("""
            import jax.random as jr
            def f(key, shape):
                return jr.normal(key, shape) + jr.normal(key, shape)
        """)
        assert rules(out) == ["KEY001"]

    def test_key002_wall_clock_key(self):
        out = keys("""
            import time
            import jax
            def f():
                return jax.random.PRNGKey(int(time.time()))
        """)
        assert rules(out) == ["KEY002"]

    def test_key002_np_random_fold(self):
        out = keys("""
            import jax
            import numpy as np
            def f(key):
                return jax.random.fold_in(key, np.random.randint(1 << 20))
        """)
        assert rules(out) == ["KEY002"]

    def test_key002_seeded_root_clean(self):
        assert keys("""
            import jax
            def f(seed):
                return jax.random.PRNGKey(seed)
        """) == []

    def test_key003_constant_collision(self):
        out = keys("""
            import jax
            def f(key):
                a = jax.random.fold_in(key, 3)
                b = jax.random.fold_in(key, 3)
                return a, b
        """)
        assert rules(out) == ["KEY003"]

    def test_key003_distinct_salts_clean(self):
        assert keys("""
            import jax
            def f(key):
                return jax.random.fold_in(key, 1), jax.random.fold_in(key, 2)
        """) == []

    def test_key003_undeclared_lattice(self):
        out = keys("""
            import jax
            def f(key, s, b):
                return jax.random.fold_in(key, s * 7 + b)
        """)
        assert rules(out) == ["KEY003"]

    def test_key003_declared_lattice_clean(self):
        # the detector's s*10+b schedule is declared
        assert keys("""
            import jax
            def f(key, s, b):
                return jax.random.fold_in(key, s * 10 + b)
        """) == []

    def test_key004_mutable_key_state(self):
        out = keys("""
            import jax
            class Engine:
                def sample(self, logits):
                    self.key, k = jax.random.split(self.key)
                    return jax.random.categorical(k, logits)
        """)
        assert rules(out) == ["KEY004"]

    def test_key004_stateless_fold_clean(self):
        assert keys("""
            import jax
            class Engine:
                def sample(self, logits, wave, step):
                    k = jax.random.fold_in(
                        jax.random.fold_in(self.root, wave), step)
                    return jax.random.categorical(k, logits)
        """) == []


# --------------------------------------------------------------- TRC rules

class TestTraceHygiene:
    def test_trc101_tracer_branch(self):
        out = trace("""
            import jax
            import jax.numpy as jnp
            @jax.jit
            def f(x):
                if jnp.sum(x) > 0:
                    return x
                return -x
        """)
        assert rules(out) == ["TRC101"]

    def test_trc101_where_clean(self):
        assert trace("""
            import jax
            import jax.numpy as jnp
            @jax.jit
            def f(x):
                return jnp.where(jnp.sum(x) > 0, x, -x)
        """) == []

    def test_trc101_static_python_branch_clean(self):
        # branching on a plain Python value is fine (static argument)
        assert trace("""
            import jax
            @jax.jit
            def f(x, per_chip):
                if per_chip:
                    return x
                return -x
        """) == []

    def test_trc101_unreachable_not_flagged(self):
        # same body, but nothing marks it jit-reachable
        assert trace("""
            import jax.numpy as jnp
            def f(x):
                if jnp.sum(x) > 0:
                    return x
                return -x
        """) == []

    def test_trc101_transitive_callee(self):
        out = trace("""
            import jax
            import jax.numpy as jnp
            def helper(x):
                while jnp.max(x) > 1:
                    x = x * 0.5
                return x
            @jax.jit
            def f(x):
                return helper(x)
        """)
        assert rules(out) == ["TRC101"]

    def test_registered_entry_point_roots(self):
        src = """
            import jax.numpy as jnp
            def entry(x):
                return float(jnp.sum(x))
        """
        assert trace(src) == []
        assert rules(trace(src, roots={"entry"})) == ["TRC102"]

    def test_trc102_item_and_numpy(self):
        out = trace("""
            import jax
            import numpy as np
            @jax.jit
            def f(x):
                y = x.item()
                return np.asarray(x) + y
        """)
        assert rules(out) == ["TRC102", "TRC102"]

    def test_trc103_bogus_static_argnames(self):
        out = trace("""
            import functools
            import jax
            @functools.partial(jax.jit, static_argnames=("cfg",))
            def f(x, config):
                return x
        """)
        assert rules(out) == ["TRC103"]

    def test_trc103_valid_static_argnames_clean(self):
        assert trace("""
            import functools
            import jax
            @functools.partial(jax.jit, static_argnames=("cfg",))
            def f(x, cfg):
                return x
        """) == []

    def test_trc103_mutable_default(self):
        out = trace("""
            import jax
            @jax.jit
            def f(x, opts={}):
                return x
        """)
        assert rules(out) == ["TRC103"]

    def test_trc104_mutable_global_capture(self):
        out = trace("""
            import jax
            _CACHE = {}
            @jax.jit
            def f(x):
                return x * _CACHE.get("scale", 1.0)
        """)
        assert rules(out) == ["TRC104"]

    def test_trc104_local_shadow_clean(self):
        assert trace("""
            import jax
            _CACHE = {}
            @jax.jit
            def f(x):
                _CACHE = {"scale": 2.0}
                return x * _CACHE["scale"]
        """) == []


# ------------------------------------------------------ baseline semantics

class TestBaseline:
    def test_identity_is_line_free(self):
        a = Finding("KEY001", "m.py", 10, "msg")
        b = Finding("KEY001", "m.py", 99, "msg")
        new, old = split_by_baseline([b], [a])
        assert new == [] and old == [b]

    def test_roundtrip(self, tmp_path):
        f = Finding("TRC102", "m.py", 3, "sync", hint="h")
        p = tmp_path / "b.json"
        write_baseline(p, [f])
        assert load_baseline(p) == [f]

    def test_clean_subtrees_enforced(self):
        for protected in ("src/repro/mc/engine.py",
                          "src/repro/serve/detector.py"):
            errs = assert_clean_subtrees([Finding("KEY001", protected, 1,
                                                  "m")])
            assert len(errs) == 1
        assert assert_clean_subtrees(
            [Finding("KEY001", "src/repro/launch/serve.py", 1, "m")]) == []


BAD_FIXTURE = textwrap.dedent("""
    import jax
    def f(key, shape):
        a = jax.random.normal(key, shape)
        b = jax.random.normal(key, shape)
        return a + b
""")


def run_cli(*argv, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *map(str, argv)],
        capture_output=True, text=True, env=env, cwd=cwd)


class TestCli:
    """`python -m repro.analysis` exit codes: the contract CI relies on."""

    def test_fail_on_new_then_baseline_then_regrow(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text(BAD_FIXTURE)
        bl = tmp_path / "baseline.json"

        r = run_cli(bad, "--passes", "keys", "--baseline", bl)
        assert r.returncode == 1, r.stdout + r.stderr
        assert "KEY001" in r.stdout

        r = run_cli(bad, "--passes", "keys", "--baseline", bl,
                    "--write-baseline")
        assert r.returncode == 0, r.stdout + r.stderr

        r = run_cli(bad, "--passes", "keys", "--baseline", bl)
        assert r.returncode == 0, r.stdout + r.stderr
        assert "[baselined]" in r.stdout

        bad.write_text(BAD_FIXTURE + textwrap.dedent("""
            def g(key, shape):
                for s in shape:
                    jax.random.normal(key, (s,))
        """))
        r = run_cli(bad, "--passes", "keys", "--baseline", bl)
        assert r.returncode == 1, r.stdout + r.stderr

        r = run_cli(bad, "--passes", "keys", "--baseline", bl,
                    "--no-fail-on-new")
        assert r.returncode == 0

    def test_json_artifact(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text(BAD_FIXTURE)
        out = tmp_path / "findings.json"
        run_cli(bad, "--passes", "keys", "--baseline",
                tmp_path / "b.json", "--json", out)
        doc = json.loads(out.read_text())
        assert [f["rule"] for f in doc["new"]] == ["KEY001"]
        assert "keys" in doc["timing_s"]

    def test_baselined_clean_subtree_exits_2(self, tmp_path):
        bl = tmp_path / "baseline.json"
        write_baseline(bl, [Finding("KEY001", "src/repro/mc/engine.py",
                                    1, "grandfathered-in-clean-subtree")])
        good = tmp_path / "good.py"
        good.write_text("x = 1\n")
        r = run_cli(good, "--passes", "keys", "--baseline", bl)
        assert r.returncode == 2
        assert "bit-exactness-critical" in r.stderr


# ------------------------------------------------------ shape contracts

class TestShapeContracts:
    def test_repo_contracts_all_pass(self):
        from repro.analysis.contracts import run_contract_pass
        assert run_contract_pass() == []

    def test_broken_entry_point_caught(self, monkeypatch):
        """A deliberately broken fixture: entry point returns transposed
        output vs its declared spec -> SHP002; a raising config -> SHP001."""
        import jax
        import jax.numpy as jnp
        import repro.analysis.contracts as contracts_mod
        from repro.analysis.registry import ShapeContract, _expect, _struct

        def broken_transpose():
            out = jax.eval_shape(lambda w, x: (x @ w).T,
                                 _struct((8, 5)), _struct((4, 8)))
            return _expect(out, (4, 5), "float32", "broken_head")

        def broken_config():
            from repro.models.detector import DetectorConfig
            DetectorConfig(stage_channels=(60,), blocks_per_stage=(12,))
            return None

        def broken_dtype():
            out = jax.eval_shape(lambda x: x.astype(jnp.bfloat16),
                                 _struct((2, 3)))
            return _expect(out, (2, 3), "float32", "dtype_drift")

        monkeypatch.setattr(
            contracts_mod, "shape_contracts",
            lambda: [ShapeContract("broken_head", "fixture.py",
                                   broken_transpose, "yolo-irc"),
                     ShapeContract("broken_cfg", "fixture.py",
                                   broken_config, "yolo-irc"),
                     ShapeContract("dtype_drift", "fixture.py",
                                   broken_dtype, "yolo-irc")])
        got = sorted(rules(contracts_mod.run_contract_pass()))
        assert got == ["SHP001", "SHP002", "SHP002"]

    def test_every_arch_has_explicit_status(self):
        from repro.configs.registry import ARCH_STATUS, list_archs
        for arch in list_archs():
            assert ARCH_STATUS.get(arch) in ("live", "legacy"), arch
        assert ARCH_STATUS["yolo-irc"] == "live"

    def test_missing_status_is_flagged(self, monkeypatch):
        import repro.configs.registry as cfg_registry
        from repro.analysis.contracts import run_contract_pass
        trimmed = {k: v for k, v in cfg_registry.ARCH_STATUS.items()
                   if k != "hymba-1.5b"}
        monkeypatch.setattr(cfg_registry, "ARCH_STATUS", trimmed)
        out = run_contract_pass()
        assert "SHP004" in rules(out)
        assert any("hymba-1.5b" in f.message for f in out)


# --------------------------------------------- runtime guards the passes pin

class TestRuntimeGuards:
    def test_detector_lattice_guard(self):
        from repro.models.detector import DetectorConfig
        with pytest.raises(ValueError, match="s\\*10\\+b"):
            DetectorConfig(stage_channels=(60, 120),
                           blocks_per_stage=(1, 10))

    def test_repo_src_is_clean(self):
        """The committed baseline is EMPTY: the whole tree must pass the
        AST passes with zero findings (the contract pass is pinned by
        test_repo_contracts_all_pass without re-tracing here)."""
        from repro.analysis.runner import run_all
        findings, _ = run_all(passes=("keys", "trace"))
        assert findings == [], "\n".join(f.format() for f in findings)

    def test_committed_baseline_empty_for_critical_subtrees(self):
        from repro.analysis.runner import DEFAULT_BASELINE
        bl = load_baseline(DEFAULT_BASELINE)
        assert assert_clean_subtrees(bl) == []
