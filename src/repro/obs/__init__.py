"""repro.obs — run manifests, phase timing, and MC convergence telemetry.

One lightweight layer used by every entry point (MC CLI, QAT drivers,
benchmarks, serving), so the whole stack speaks one telemetry format:

  RunLog / NullRunLog    `experiments/<run_id>/` writer: manifest.json
                         (args, git SHA, jax versions, host, backend),
                         append-only metrics.jsonl, per-chip .npy arrays,
                         optional jax.profiler trace
  PhaseTimer / timed_step  first-call compile time split from steady-state
                         execute time; chips/sec, steps/sec, tokens/sec
  LatencyTracker         exact submit→response latency percentiles
                         (the serving engine's queue-latency telemetry)
  ConvergenceMonitor     standard-error-of-the-mean per metric after each
                         MC chunk + optional `stderr_target` early stop
  collect_env / git_sha  provenance helpers (also stamped into
                         BENCH_mc.json so drift baselines are interpretable)

See README "Observability" for the run-directory layout and how to replay
a metrics.jsonl stream.
"""
from repro.obs.runlog import (RunLog, NullRunLog, NULL_RUNLOG, as_runlog,
                              collect_env, git_sha)
from repro.obs.timers import (PhaseTimer, LatencyTracker, timed_step,
                              maybe_runlog)
from repro.obs.convergence import ConvergenceMonitor

__all__ = ["RunLog", "NullRunLog", "NULL_RUNLOG", "as_runlog", "collect_env",
           "git_sha", "PhaseTimer", "LatencyTracker", "timed_step",
           "maybe_runlog", "ConvergenceMonitor"]
