"""Phase timers that split first-call (trace + compile + execute) latency
from steady-state throughput.

A single `wall_s` over a jitted loop conflates XLA compilation with the
steady state the system actually operates in — at small workloads the
compile dominates and every derived rate (chips/sec, steps/sec, tokens/sec)
is misleading.  `PhaseTimer` counts the FIRST lap separately (`compile_s`;
strictly it is first-call latency — on a warm jit cache it contains no
compilation, which is itself worth seeing) and derives rates from the
remaining laps only, falling back to the total when a phase ran one lap.
"""
from __future__ import annotations

import contextlib
import time
from typing import Dict, Optional


class _Lap:
    """Mutable handle yielded by `PhaseTimer.lap()`: set `.items` inside the
    block when the work amount is only known after it ran (e.g. tokens
    decoded until EOS)."""

    def __init__(self, items: float):
        self.items = items


class PhaseTimer:
    """Accumulates laps of one phase; first lap is the compile/warmup lap."""

    def __init__(self, phase: str, unit: str = "items"):
        self.phase = phase
        self.unit = unit
        self.compile_s = 0.0        # first-lap wall (includes jit compile)
        self.compile_items = 0.0
        self.steady_s = 0.0         # laps 2..n wall
        self.steady_items = 0.0
        self.laps = 0
        self.last_s = 0.0

    @contextlib.contextmanager
    def lap(self, items: float = 0.0):
        t0 = time.perf_counter()
        handle = _Lap(items)
        try:
            yield handle
        finally:
            dt = time.perf_counter() - t0
            self.last_s = dt
            if self.laps == 0:
                self.compile_s += dt
                self.compile_items += handle.items
            else:
                self.steady_s += dt
                self.steady_items += handle.items
            self.laps += 1

    @property
    def total_s(self) -> float:
        return self.compile_s + self.steady_s

    @property
    def total_items(self) -> float:
        return self.compile_items + self.steady_items

    def rate(self) -> float:
        """Steady-state `unit`/sec (laps after the first); single-lap phases
        fall back to the total — the honest number when nothing amortized."""
        if self.laps >= 2 and self.steady_items > 0:
            return self.steady_items / max(self.steady_s, 1e-9)
        return self.total_items / max(self.total_s, 1e-9)

    def summary(self) -> Dict[str, float]:
        return {
            "phase": self.phase,
            "laps": self.laps,
            "compile_s": self.compile_s,
            "steady_s": self.steady_s,
            "total_s": self.total_s,
            self.unit: self.total_items,
            f"{self.unit}_per_sec": self.rate(),
        }

    def log_to(self, runlog, **extra) -> None:
        """Emit a `phase` event through a RunLog (no-op on NullRunLog)."""
        runlog.log_event("phase", **self.summary(), **extra)


class LatencyTracker:
    """Per-item latency accumulator with percentile summaries.

    The serving engine records one submit→response latency per request;
    `summary()` reports count/mean and the p50/p95/p99 the queue-latency
    benchmark rows and `serve_wave` RunLog events carry.  Values are kept
    raw (a float per item) — exact percentiles, same philosophy as
    `StreamingMoments`' exact quantiles."""

    def __init__(self, unit: str = "s"):
        self.unit = unit
        self._values: list = []

    def add(self, seconds: float) -> None:
        """Record one item's latency."""
        self._values.append(float(seconds))

    @property
    def count(self) -> int:
        """Number of recorded latencies."""
        return len(self._values)

    def summary(self) -> Dict[str, float]:
        """count/mean/p50/p95/p99 over everything recorded so far."""
        import numpy as np
        if not self._values:
            return {"count": 0.0}
        v = np.asarray(self._values, np.float64)
        return {"count": float(v.size), "mean": float(v.mean()),
                "p50": float(np.percentile(v, 50)),
                "p95": float(np.percentile(v, 95)),
                "p99": float(np.percentile(v, 99))}


def timed_step(step_fn, timer: PhaseTimer, block_on=None):
    """Wrap a jitted step so every call is one timer lap (first call =
    compile lap).  `block_on(result)` selects what to block_until_ready on;
    defaults to the whole result tree."""
    import jax

    def wrapped(*args, **kwargs):
        with timer.lap(items=1):
            out = step_fn(*args, **kwargs)
            jax.block_until_ready(out if block_on is None else block_on(out))
        return out

    return wrapped


def maybe_runlog(enabled: bool, name: str, *, args=None, root: str =
                 "experiments", run_id: Optional[str] = None):
    """`RunLog.create` when enabled, else the no-op singleton — the common
    CLI pattern behind `--run-dir`."""
    from repro.obs.runlog import NULL_RUNLOG, RunLog
    if not enabled:
        return NULL_RUNLOG
    return RunLog.create(name, args=args, root=root, run_id=run_id)
