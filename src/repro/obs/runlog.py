"""Run manifests + append-only metric event streams (the `repro.obs` core).

Every instrumented entry point — `launch.mc`, `launch.train`,
`examples/train_detector.py`, `benchmarks/mc_bench.py`, the serving engine —
speaks this one telemetry format.  A run is a directory:

  experiments/<run_id>/
    manifest.json     provenance: argv/args, git SHA, jax/jaxlib versions,
                      host, backend, device count, timestamps
    metrics.jsonl     append-only event stream; one JSON object per line,
                      each with a monotonic `t` (seconds since run start)
                      and a `kind` ("chunk", "convergence", "phase", ...)
    *.npy             arrays persisted via `save_array` (per-chip metric
                      vectors from `McResult.per_chip`)
    trace/            optional `jax.profiler` trace (`--trace`)

`metrics.jsonl` is the run's evidence, not just its log: per-chunk events
carry the raw per-chip metric values, so replaying the stream through the
same Welford accumulators reproduces the reported population mean±std
bit-for-bit (tests/test_obs.py pins this).

`NullRunLog` (singleton `NULL_RUNLOG`, via `as_runlog(None)`) is the no-op
twin, so library code instruments unconditionally and pays nothing when no
run directory was requested.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
import uuid
from pathlib import Path
from typing import Any, Dict, Optional


def git_sha() -> Optional[str]:
    """HEAD SHA of the source tree this module runs from (None outside git)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=Path(__file__).resolve().parent,
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except Exception:
        return None


def collect_env() -> Dict[str, Any]:
    """Host / toolchain metadata: what makes machine-relative numbers
    interpretable across machines (also merged into BENCH_mc.json)."""
    import platform
    import socket
    info: Dict[str, Any] = {
        "host": socket.gethostname(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count(),
    }
    try:
        import jax
        import jaxlib
        info.update({"jax": jax.__version__, "jaxlib": jaxlib.__version__,
                     "backend": jax.default_backend(),
                     "device_count": jax.device_count()})
    except Exception:       # pragma: no cover - jax is a hard dep in practice
        pass
    return info


def _jsonable(v):
    """numpy scalars/arrays and jax arrays -> plain python for json.dumps."""
    if hasattr(v, "tolist"):
        return v.tolist()
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, (str, int, bool)) or v is None:
        return v
    if isinstance(v, float):
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return repr(v)


class RunLog:
    """Writer for one `experiments/<run_id>/` run directory."""

    def __init__(self, run_dir: Path, manifest: Dict[str, Any]):
        self.path = Path(run_dir)
        self.path.mkdir(parents=True, exist_ok=True)
        self.manifest = manifest
        self._t0 = time.perf_counter()
        self._events = self.path / "metrics.jsonl"
        self._tracing = False
        self._write_manifest()

    # ------------------------------------------------------------- creation

    @classmethod
    def create(cls, name: str, *, args: Optional[Dict[str, Any]] = None,
               root: str = "experiments",
               run_id: Optional[str] = None) -> "RunLog":
        """Create `root/<run_id>/` and write its manifest.

        `run_id` defaults to `<utc-timestamp>-<name>-<6 hex>` — sortable,
        collision-free across concurrent runs on one host.
        """
        run_id = run_id or (time.strftime("%Y%m%d-%H%M%S", time.gmtime())
                            + f"-{name}-{uuid.uuid4().hex[:6]}")
        manifest = {
            "run_id": run_id,
            "name": name,
            "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "argv": list(sys.argv),
            "args": _jsonable(args) if args is not None else None,
            "git_sha": git_sha(),
            "env": collect_env(),
            "status": "running",
        }
        return cls(Path(root) / run_id, manifest)

    def _write_manifest(self) -> None:
        (self.path / "manifest.json").write_text(
            json.dumps(self.manifest, indent=1, default=_jsonable))

    # --------------------------------------------------------------- events

    def log_event(self, kind: str, **fields) -> None:
        """Append one event line to metrics.jsonl."""
        ev = {"t": round(time.perf_counter() - self._t0, 6), "kind": kind}
        ev.update({k: _jsonable(v) for k, v in fields.items()})
        with self._events.open("a") as f:
            f.write(json.dumps(ev) + "\n")

    # ------------------------------------------------------------ artifacts

    def save_array(self, name: str, arr) -> Path:
        """Persist an array as `<name>.npy` under the run dir."""
        import numpy as np
        out = self.path / f"{name}.npy"
        out.parent.mkdir(parents=True, exist_ok=True)
        np.save(out, np.asarray(arr))
        return out

    def save_result(self, label: str, metrics: Dict[str, Dict[str, float]],
                    per_chip: Optional[Dict[str, Any]] = None,
                    **fields) -> None:
        """One sweep's summary event + its per-chip metric vectors as .npy."""
        self.log_event("result", label=label, metrics=metrics, **fields)
        for name, vec in (per_chip or {}).items():
            self.save_array(f"per_chip_{name}_{label}", vec)

    def write_text(self, name: str, text: str) -> Path:
        out = self.path / name
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(text)
        return out

    # -------------------------------------------------------------- tracing

    def start_trace(self) -> bool:
        """Capture a `jax.profiler` trace into `<run_dir>/trace/`."""
        try:
            import jax
            jax.profiler.start_trace(str(self.path / "trace"))
            self._tracing = True
        except Exception as e:   # profiler backends vary across jax versions
            self.log_event("trace_error", error=f"{type(e).__name__}: {e}")
            self._tracing = False
        return self._tracing

    def stop_trace(self) -> None:
        if not self._tracing:
            return
        try:
            import jax
            jax.profiler.stop_trace()
        except Exception as e:
            self.log_event("trace_error", error=f"{type(e).__name__}: {e}")
        self._tracing = False

    # ------------------------------------------------------------- finalize

    def finalize(self, status: str = "ok", **summary) -> None:
        self.stop_trace()
        self.manifest["status"] = status
        self.manifest["wall_s"] = round(time.perf_counter() - self._t0, 6)
        if summary:
            self.manifest["summary"] = _jsonable(summary)
        self._write_manifest()


class NullRunLog(RunLog):
    """No-op RunLog: library code logs unconditionally, callers that didn't
    ask for a run directory pay nothing and write nothing."""

    def __init__(self):          # noqa: super().__init__ deliberately skipped
        self.path = None
        self.manifest = {}
        self._tracing = False

    def log_event(self, kind: str, **fields) -> None:
        pass

    def save_array(self, name: str, arr):
        return None

    def save_result(self, label, metrics, per_chip=None, **fields) -> None:
        pass

    def write_text(self, name: str, text: str):
        return None

    def start_trace(self) -> bool:
        return False

    def stop_trace(self) -> None:
        pass

    def finalize(self, status: str = "ok", **summary) -> None:
        pass


NULL_RUNLOG = NullRunLog()


def as_runlog(obs: Optional[RunLog]) -> RunLog:
    """None -> the no-op singleton; a RunLog passes through."""
    return NULL_RUNLOG if obs is None else obs
