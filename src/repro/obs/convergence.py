"""MC convergence telemetry: standard error of the mean, streamed per chunk.

The paper's claim is statistical (population mAP mean±std over sampled
chips), so the evidence quality is the standard error of that mean —
std/sqrt(n_chips) — not the chip count alone.  `ConvergenceMonitor` sits on
the engine's Welford accumulators, emits a `convergence` event after every
chunk (running count/mean/stderr per metric), and answers whether an
optional `stderr_target` has been reached so `run_mc`/`run_mc_detector` can
stop early: chips are keyed by id, so an early-stopped run is bit-identical
to the same-length prefix of the full run (tests pin this).
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.obs.runlog import NULL_RUNLOG, RunLog


class ConvergenceMonitor:
    """Streams per-metric stderr from `StreamingMoments` accumulators.

    `moments` is the engine's name -> StreamingMoments dict (duck-typed on
    `.count`/`.mean_value`/`.stderr()`); `stderr_metric` narrows the
    early-stop criterion to one metric (default: ALL tracked metrics must
    reach the target).  With `stderr_target=None` the monitor only logs.
    """

    def __init__(self, moments: Dict[str, object], *,
                 stderr_target: Optional[float] = None,
                 stderr_metric: Optional[str] = None,
                 runlog: RunLog = NULL_RUNLOG, phase: str = "mc"):
        if stderr_metric is not None and stderr_metric not in moments:
            raise ValueError(f"stderr_metric {stderr_metric!r} is not a "
                             f"tracked metric (have: {sorted(moments)})")
        self.moments = moments
        self.stderr_target = stderr_target
        self.stderr_metric = stderr_metric
        self.runlog = runlog
        self.phase = phase

    def _gated(self) -> Dict[str, object]:
        if self.stderr_metric is None:
            return self.moments
        return {self.stderr_metric: self.moments[self.stderr_metric]}

    def converged(self) -> bool:
        """True iff a target is set and every gated metric's stderr (needs
        >= 2 chips for a defined std) is at or under it."""
        if self.stderr_target is None:
            return False
        return all(m.stderr() <= self.stderr_target
                   for m in self._gated().values())

    def after_chunk(self, chunk: int, chips_done: int) -> bool:
        """Log the running stats; return True when early-stop should fire."""
        self.runlog.log_event(
            "convergence", phase=self.phase, chunk=chunk, chips=chips_done,
            stderr_target=self.stderr_target,
            metrics={name: {"count": m.count, "mean": m.mean_value,
                            "stderr": m.stderr()}
                     for name, m in self.moments.items()})
        return self.converged()
