"""repro.configs — assigned architectures (+ the paper's own model).

``registry.get_config(arch_id, variant)`` resolves ``--arch`` flags;
``shapes.SHAPES`` holds the assigned input shapes.
"""
