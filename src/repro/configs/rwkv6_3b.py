"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free, head_size 64 -> 40 heads)
d_ff=8960 vocab=65536 — Finch, data-dependent decay [arXiv:2404.05892; hf].
O(1) decode state -> `long_500k` RUNS."""
from repro.models.lm_config import LMConfig

ARCH_ID = "rwkv6-3b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
        head_dim=64, d_ff=8960, vocab_size=65536,
        block="rwkv", pos="none", dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=224, vocab_size=128,
        block="rwkv", pos="none", dtype="float32", param_dtype="float32")
