"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24 -> MHA, head_dim=64)
d_ff=6144 vocab=2048 — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].  The EnCodec modality frontend is a STUB per the
assignment: input_specs() provides precomputed frame token ids in the
codebook vocab.  GELU FFN, sinusoidal positions.  Full attention ->
`long_500k` skipped."""
from repro.models.lm_config import LMConfig

ARCH_ID = "musicgen-medium"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
        head_dim=64, d_ff=6144, vocab_size=2048,
        act="gelu", pos="sinusoidal", frontend="embed",
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128,
        act="gelu", pos="sinusoidal", dtype="float32", param_dtype="float32")
