"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8, head_dim=128)
d_ff=2048 (per expert) vocab=163840, MoE 384 experts top-8, one dense
prefix layer — trillion-param MoE (paper-table) [arXiv:2501.kimi2;
unverified].  Full attention -> `long_500k` skipped."""
from repro.models.lm_config import LMConfig

ARCH_ID = "kimi-k2-1t-a32b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=2048, vocab_size=163840,
        moe=True, n_experts=384, top_k=8, n_dense_prefix=1,
        rope_theta=50000.0, dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
        moe=True, n_experts=8, top_k=2, n_dense_prefix=1,
        dtype="float32", param_dtype="float32")
