"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5, head_dim=64)
d_ff=5504 vocab=32001, ssm_state=16 — parallel attn+mamba heads
[arXiv:2411.13676; hf].  Sliding-window attention on most layers (global at
first/middle/last), so `long_500k` RUNS."""
from repro.models.lm_config import LMConfig

ARCH_ID = "hymba-1.5b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        head_dim=64, d_ff=5504, vocab_size=32001,
        block="hybrid", attn_pattern="local_mostly", window=1024,
        ssm_state=16, rope_theta=10000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=80, n_heads=5,
        n_kv_heads=1, head_dim=16, d_ff=160, vocab_size=128,
        block="hybrid", attn_pattern="local_mostly", window=8,
        ssm_state=4, dtype="float32", param_dtype="float32")
