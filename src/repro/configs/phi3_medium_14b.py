"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE SwiGLU GQA [arXiv:2404.14219; unverified].
Full attention -> `long_500k` skipped."""
from repro.models.lm_config import LMConfig

ARCH_ID = "phi3-medium-14b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
        head_dim=128, d_ff=17920, vocab_size=100352,
        rope_theta=10000.0, dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, head_dim=16, d_ff=224, vocab_size=128,
        dtype="float32", param_dtype="float32")
