"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8, head_dim=128)
d_ff=22016 vocab=65536 — early-fusion, VQ image tokens
[arXiv:2405.09818; unverified].  Early fusion means the modality frontend
IS the unified token embedding: the VQ tokenizer is a stub per the
assignment and input_specs() provides precomputed token ids (text + image
VQ codes share the 65536 vocab).  QK-norm for stability.  Full attention ->
`long_500k` skipped."""
from repro.models.lm_config import LMConfig

ARCH_ID = "chameleon-34b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=22016, vocab_size=65536,
        qk_norm=True, rope_theta=10000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=160, vocab_size=128,
        qk_norm=True, dtype="float32", param_dtype="float32")
