"""Architecture registry: ``--arch <id>`` resolution for launch tools,
plus the explicit liveness map the static-analysis shape pass keys on."""
from __future__ import annotations

from typing import Dict, List

from repro.models.lm_config import LMConfig
from repro.configs import (hymba_1p5b, phi3_medium_14b, deepseek_67b,
                           gemma2_27b, llama3_405b, qwen3_moe_235b,
                           kimi_k2_1t, musicgen_medium, rwkv6_3b,
                           chameleon_34b)

_MODULES = {
    m.ARCH_ID: m for m in (
        hymba_1p5b, phi3_medium_14b, deepseek_67b, gemma2_27b, llama3_405b,
        qwen3_moe_235b, kimi_k2_1t, musicgen_medium, rwkv6_3b, chameleon_34b)
}

# Liveness of every registered arch — `repro.analysis` (SHP003/SHP004)
# refuses to run if an arch is missing here, so quarantine is explicit:
#   "live"   — on the paper's detector/MC path; must carry shape contracts
#              in repro.analysis.registry.shape_contracts()
#   "legacy" — LM model-zoo weight kept for its smoke tests only; NOT
#              reachable from the detector path or any launch CLI it ships;
#              the shape pass still abstract-evals its smoke config so
#              quarantined code cannot rot silently
ARCH_STATUS: Dict[str, str] = {
    "yolo-irc": "live",
    **{arch: "legacy" for arch in _MODULES},
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def get_config(arch: str, variant: str = "full") -> LMConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = _MODULES[arch]
    if variant == "full":
        return mod.full()
    if variant == "smoke":
        return mod.smoke()
    raise ValueError(f"unknown variant {variant!r}")
