"""Assigned input shapes (same 4 for every LM arch; 40 cells total).

``train_4k`` lowers train_step; ``prefill_32k`` lowers the prefill forward;
``decode_32k`` / ``long_500k`` lower serve_step (one new token against a KV
cache of seq_len).  ``long_500k`` requires sub-quadratic attention — it runs
for SSM/hybrid archs (hymba, rwkv6) and is SKIPPED for pure full-attention
archs (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.models.lm_config import LMConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: LMConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for one (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return False, ("full quadratic attention at 524k context; assigned "
                       "skip for pure full-attention archs (sub-quadratic "
                       "only: hymba/rwkv6)")
    return True, ""
