"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8, head_dim=128)
d_ff=53248 vocab=128256 — GQA 128k vocab [arXiv:2407.21783; unverified].
Full attention -> `long_500k` skipped."""
from repro.models.lm_config import LMConfig

ARCH_ID = "llama3-405b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
        head_dim=128, d_ff=53248, vocab_size=128256,
        rope_theta=500000.0, dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=128, n_heads=8,
        n_kv_heads=1, head_dim=16, d_ff=416, vocab_size=256,
        rope_theta=500000.0, dtype="float32", param_dtype="float32")
