"""The paper's own model: YOLOv2-style IRC object detector (Fig. 11).

Six binary group-conv layers (the paper's Table I names them Layer2_0,
Layer2_1, Layer3_0..Layer3_3), group size 60, digital stem + head, evaluated
on 1024x576 inputs (IVS 3cls geometry; dataset is synthetic here — see
DESIGN.md).  `proposed()` and `baseline()` mirror the Table II designs.
"""
from repro.models.detector import DetectorConfig

ARCH_ID = "yolo-irc"


def proposed() -> DetectorConfig:
    """Ternary 20/60/20, no BN, single-shot accumulation, 32 bias rows."""
    return DetectorConfig(
        img_hw=(576, 1024), n_classes=3, n_anchors=5, group=60,
        stage_channels=(60, 120, 240), blocks_per_stage=(2, 2, 2),
        scheme="ternary", use_bn=False, accumulation="single_shot",
        bias_rows=32)


def baseline() -> DetectorConfig:
    """Binary weights vs shared reference, in-memory BN (96 rows),
    partial-sum accumulation (~300 uA per 212-row chunk at nominal WL)."""
    return DetectorConfig(
        img_hw=(576, 1024), n_classes=3, n_anchors=5, group=60,
        stage_channels=(60, 120, 240), blocks_per_stage=(2, 2, 2),
        scheme="binary", use_bn=True, accumulation="partial_sum",
        bias_rows=0, partial_rows=212)


def smoke(scheme: str = "ternary") -> DetectorConfig:
    kwargs = dict(img_hw=(32, 32), stage_channels=(60, 120),
                  blocks_per_stage=(1, 1), n_classes=3, n_anchors=2)
    if scheme == "ternary":
        return DetectorConfig(scheme="ternary", use_bn=False,
                              accumulation="single_shot", bias_rows=16,
                              **kwargs)
    return DetectorConfig(scheme="binary", use_bn=True,
                          accumulation="partial_sum", bias_rows=0, **kwargs)
