"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4, head_dim=128)
d_ff=1536 (per expert) vocab=151936, MoE 128 experts top-8
[hf:Qwen/Qwen3-30B-A3B; hf].  Full attention -> `long_500k` skipped."""
from repro.models.lm_config import LMConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
        head_dim=128, d_ff=1536, vocab_size=151936,
        moe=True, n_experts=128, top_k=8, qk_norm=True,
        rope_theta=1000000.0, dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
        moe=True, n_experts=8, top_k=2, qk_norm=True,
        dtype="float32", param_dtype="float32")
