"""deepseek-67b [dense]: 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400 — llama-arch [arXiv:2401.02954; hf].
Full attention -> `long_500k` skipped."""
from repro.models.lm_config import LMConfig

ARCH_ID = "deepseek-67b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=22016, vocab_size=102400,
        rope_theta=10000.0, dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=64, n_heads=8,
        n_kv_heads=1, head_dim=8, d_ff=160, vocab_size=128,
        dtype="float32", param_dtype="float32")
