"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16, head_dim=128)
d_ff=36864 vocab=256000 — local+global alternating, logit softcap
[arXiv:2408.00118; hf].  Global layers are full attention -> `long_500k`
skipped."""
from repro.models.lm_config import LMConfig

ARCH_ID = "gemma2-27b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
        head_dim=128, d_ff=36864, vocab_size=256000,
        attn_pattern="alt_local_global", window=4096,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        post_norm=True, norm_plus_one=True, tie_embeddings=True,
        embed_scale=True, rope_theta=10000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=256, vocab_size=256,
        attn_pattern="alt_local_global", window=8,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        post_norm=True, norm_plus_one=True, tie_embeddings=True,
        embed_scale=True, dtype="float32", param_dtype="float32")
