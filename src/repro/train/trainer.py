"""Training loop with fault-tolerance plumbing.

  * resume-from-latest checkpoint (exact: stateless-seeded data pipeline)
  * async keep-k checkpointing every `ckpt_every` steps
  * straggler watchdog: per-step wall time is tracked; steps slower than
    `straggler_factor` x the running median are logged — on a real fleet
    this feeds the scheduler's hot-spare replacement signal, here it
    surfaces CPU noise / compilation stalls
  * metrics history is returned for tests / examples to assert on
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.obs import PhaseTimer, RunLog, as_runlog
from repro.train.steps import TrainState

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 batch_fn: Callable[[int], Dict],
                 state: TrainState, obs: Optional[RunLog] = None):
        self.cfg = cfg
        self.train_step = jax.jit(train_step, donate_argnums=(0,))
        self.batch_fn = batch_fn
        self.state = state
        self.history: List[Dict[str, float]] = []
        self.straggler_steps: List[int] = []
        self.ckpt = (CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts)
                     if cfg.ckpt_dir else None)
        self.obs = as_runlog(obs)
        self.step_timer = PhaseTimer("train_step", unit="steps")

    def maybe_resume(self) -> int:
        if self.ckpt is None:
            return 0
        restored, step = self.ckpt.restore_latest(
            jax.eval_shape(lambda: self.state))
        if restored is None:
            return 0
        self.state = restored
        return int(step)

    def run(self) -> List[Dict[str, float]]:
        start = self.maybe_resume()
        step_times: List[float] = []
        for step in range(start, self.cfg.total_steps):
            batch = self.batch_fn(step)
            with self.step_timer.lap(items=1):
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            dt = self.step_timer.last_s
            step_times.append(dt)
            if len(step_times) > 5:
                med = float(np.median(step_times[-50:]))
                if dt > self.cfg.straggler_factor * med:
                    self.straggler_steps.append(step)
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = step
            rec["step_time_s"] = dt
            self.history.append(rec)
            if self.cfg.log_every and step % self.cfg.log_every == 0:
                print(f"step {step:5d} loss {rec['loss']:.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
                self.obs.log_event("train_step", **rec)
            if self.ckpt and (step + 1) % self.cfg.ckpt_every == 0:
                self.ckpt.save_async(self.state, step + 1)
                self.obs.log_event("checkpoint", step=step + 1)
        self.step_timer.log_to(self.obs, stragglers=len(self.straggler_steps))
        if self.ckpt:
            self.ckpt.wait()
            from repro.ckpt import latest_step
            if latest_step(self.ckpt.directory) != self.cfg.total_steps:
                self.ckpt.save(self.state, self.cfg.total_steps)
        return self.history
