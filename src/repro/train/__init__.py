from repro.train.steps import (TrainState, make_train_step, make_eval_step,
                               make_decode_step, abstract_train_state,
                               make_det_qat_step, ensemble_key_for_step)
