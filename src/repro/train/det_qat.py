"""Short detector QAT loop shared by the MC CLI and the benchmark tables.

One jitted AdamW step over the synthetic detection batches — enough training
for population-mAP sweeps to be ordering-meaningful on smoke geometries.
The paper-scale driver (`examples/train_detector.py`) keeps its own richer
loop (LR schedule, noise-aware QAT, logging) on the SAME step builder
(`repro.train.steps.make_det_qat_step`); this helper exists so the
CLI/benchmark call sites don't each carry a drifting copy of the same step.

`train_chips` turns on ensemble-aware QAT: every step trains against a small
chip population (deviation planes keyed by the established `fold_in` stream,
resampled every `resample_every` steps) instead of one i.i.d. noise draw.
`train_chips=1` (default) is the legacy single-draw step, bit-for-bit.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_init
from repro.train.steps import ensemble_key_for_step, make_det_qat_step


def quick_qat(det, data, steps: int, batch: int, *, lr: float = 3e-3,
              weight_decay: float = 1e-3, seed: int = 0, data_seed: int = 1,
              key: Optional[jax.Array] = None, train_chips: int = 1,
              resample_every: int = 1, cfg_ni=None):
    """Train `det` for `steps` AdamW steps on `data` and return params.

    `key` (defaults to `PRNGKey(data_seed)`, the historical stream) is the
    single root of the run: per-step surrogate-noise keys are
    `fold_in(key, s)` and — for `train_chips >= 2` — chip populations are
    keyed `ensemble_key_for_step(key, s, resample_every)`, so CLI/benchmark
    callers reproduce a run from one root key.
    """
    params = det.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    step = jax.jit(make_det_qat_step(
        det, train_chips=train_chips, cfg_ni=cfg_ni,
        opt_cfg=AdamWConfig(weight_decay=weight_decay)))

    root = jax.random.PRNGKey(data_seed) if key is None else key
    lr32 = jnp.float32(lr)
    for s in range(steps):
        b = data.batch_for_step(s, batch)
        params, opt, _ = step(params, opt, b.images, b.targets, lr32,
                              jax.random.fold_in(root, s),
                              ensemble_key_for_step(root, s, resample_every))
    return params
