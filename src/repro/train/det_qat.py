"""Short detector QAT loop shared by the MC CLI and the benchmark tables.

One jitted AdamW step over the synthetic detection batches — enough training
for population-mAP sweeps to be ordering-meaningful on smoke geometries.
The paper-scale driver (`examples/train_detector.py`) keeps its own richer
loop (LR schedule, noise-aware QAT, logging); this helper exists so the
CLI/benchmark call sites don't each carry a drifting copy of the same step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train.det_loss import yolo_loss


def quick_qat(det, data, steps: int, batch: int, *, lr: float = 3e-3,
              weight_decay: float = 1e-3, seed: int = 0, data_seed: int = 1):
    """Train `det` for `steps` AdamW steps on `data` and return params."""
    params = det.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    ocfg = AdamWConfig(weight_decay=weight_decay)

    @jax.jit
    def step(params, opt, images, targets, k):
        def loss_fn(p):
            pred = det.apply(p, images, mode="train", key=k)
            return yolo_loss(pred, targets, det.cfg.n_anchors,
                             det.cfg.n_classes)
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, _ = adamw_update(grads, opt, params, jnp.float32(lr),
                                      ocfg)
        return params, opt, loss

    for s in range(steps):
        b = data.batch_for_step(s, batch)
        params, opt, _ = step(params, opt, b.images, b.targets,
                              jax.random.fold_in(
                                  jax.random.PRNGKey(data_seed), s))
    return params
