"""jit-able train / eval / decode steps shared by the trainer, the serving
engine, and the multi-pod dry-run.

`make_train_step(lm, ...)` returns a pure function
    (state, batch) -> (state, metrics)
with loss+grad under remat, global-norm clipping, AdamW, and the paper's LR
schedule; everything pjit-shards via the in/out shardings the caller derives
from `repro.sharding.rules`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import LM
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         warmup_step_decay)

PyTree = Any


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt: PyTree
    step: jax.Array


jax.tree_util.register_pytree_with_keys(
    TrainState,
    lambda s: ((("params", s.params), ("opt", s.opt), ("step", s.step)),
               None),
    lambda aux, c: TrainState(*c))


def init_train_state(lm: LM, key: jax.Array) -> TrainState:
    params = lm.init(key)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def abstract_train_state(lm: LM) -> TrainState:
    """ShapeDtypeStruct TrainState (no allocation) for AOT lowering."""
    params = lm.abstract_params()
    opt = jax.eval_shape(adamw_init, params)
    return TrainState(params=params, opt=opt,
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def train_state_axes(lm: LM) -> TrainState:
    """Logical-axes TrainState matching abstract_train_state (moments share
    the param sharding; step is replicated)."""
    axes = lm.logical_axes()
    return TrainState(
        params=axes,
        opt={"m": axes, "v": axes, "step": ()},
        step=())


def make_train_step(lm: LM, *, opt_cfg: AdamWConfig = AdamWConfig(),
                    lr_fn: Optional[Callable] = None, remat: str = "block",
                    microbatch: int = 1, scan_layers: bool = True,
                    scan_microbatches: bool = True
                    ) -> Callable[[TrainState, Dict[str, jax.Array]],
                                  Tuple[TrainState, Dict[str, jax.Array]]]:
    """scan_microbatches=False unrolls the grad-accumulation loop — used by
    the roofline cost probes (XLA cost_analysis counts a scanned microbatch
    body once regardless of trip count)."""
    lr_fn = lr_fn or (lambda s: warmup_step_decay(s))

    def loss_fn(params, batch):
        return lm.loss(params, batch, remat=remat, scan_layers=scan_layers)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatch > 1:
            # gradient accumulation over leading micro-slices of the batch
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), metrics

            mb_batch = jax.tree.map(
                lambda x: x.reshape((microbatch, x.shape[0] // microbatch)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            if scan_microbatches:
                (grads, loss_sum), metrics = jax.lax.scan(
                    micro, (zeros, jnp.zeros((), jnp.float32)), mb_batch)
                metrics = jax.tree.map(lambda m: m[-1], metrics)
            else:
                carry = (zeros, jnp.zeros((), jnp.float32))
                for i in range(microbatch):
                    carry, metrics = micro(
                        carry, jax.tree.map(lambda x: x[i], mb_batch))
                grads, loss_sum = carry
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = loss_sum / microbatch
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        lr = lr_fn(state.step)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, lr, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["lr"] = lr
        metrics["loss"] = loss
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1)
        return new_state, metrics

    return train_step


# ------------------------------------------------------------- detector QAT

# Salt separating the chip-population key stream from the per-step noise
# stream (`fold_in(root, step)`), so one root key reproduces a whole QAT run.
ENSEMBLE_KEY_STREAM = 0x0E25


def ensemble_key_for_step(key: jax.Array, step: int,
                          resample_every: int = 1) -> jax.Array:
    """Chip-population key for QAT step `step`.

    Advances every `resample_every` steps: within a window the population's
    variation masks are FROZEN (the same dies are seen while their planes are
    rebuilt from the current quantized weights each step), and the dies are
    resampled exactly on schedule.
    """
    assert resample_every >= 1, resample_every
    return jax.random.fold_in(jax.random.fold_in(key, ENSEMBLE_KEY_STREAM),
                              step // resample_every)


def make_det_qat_step(det, *, train_chips: int = 1,
                      cfg_ni=None,
                      opt_cfg: AdamWConfig = AdamWConfig(weight_decay=1e-3)
                      ) -> Callable:
    """Build the detector QAT step shared by `quick_qat`, the MC CLI and the
    paper-scale driver:

        (params, opt, images, targets, lr, key, ens_key)
            -> (params, opt, loss)

    `train_chips=1` (default) is EXACTLY the legacy single-draw step — loss
    through `mode="train"` with one surrogate-noise draw keyed `key`;
    `ens_key` is ignored.  Bit-identity with the historical `quick_qat` step
    is a guarantee (tests pin it).

    `train_chips>=2` is ensemble-aware QAT (paper Sec. V at population
    scale): the step draws a `train_chips` deviation population keyed
    `ens_key` (`repro.mc.build_train_ensemble` — planes from the CURRENT
    quantized weights, chip identity frozen between `ens_key` changes), runs
    `mode="train_ensemble"`, and averages the loss over chip realizations by
    folding the chips axis into the batch.
    """
    from repro.core import nonideal as ni
    from repro.train.det_loss import yolo_loss
    if train_chips < 1:
        raise ValueError(f"train_chips must be >= 1, got {train_chips}")
    cfg_ni = ni.NonidealConfig.none() if cfg_ni is None else cfg_ni

    def qat_step(params, opt, images, targets, lr, key, ens_key):
        def loss_fn(p):
            if train_chips == 1:
                pred = det.apply(p, images, mode="train", key=key,
                                 cfg_ni=cfg_ni)
                return yolo_loss(pred, targets, det.cfg.n_anchors,
                                 det.cfg.n_classes)
            from repro.mc.detector_mc import build_train_ensemble
            ens = build_train_ensemble(ens_key, det, p, train_chips,
                                       cfg=cfg_ni)
            pred = det.apply(p, images, mode="train_ensemble", key=key,
                             cfg_ni=cfg_ni, ensemble=ens)
            pred = pred.reshape((-1,) + pred.shape[2:])   # chips into batch
            tiled = jax.tree.map(
                lambda t: jnp.tile(t, (train_chips,) + (1,) * (t.ndim - 1)),
                targets)
            return yolo_loss(pred, tiled, det.cfg.n_anchors,
                             det.cfg.n_classes)
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, _ = adamw_update(grads, opt, params, lr, opt_cfg)
        return params, opt, loss

    return qat_step


def make_eval_step(lm: LM) -> Callable:
    def eval_step(params, batch):
        _, metrics = lm.loss(params, batch, remat="none")
        return metrics
    return eval_step


def make_decode_step(lm: LM) -> Callable:
    def decode_step(params, tokens, cache):
        return lm.decode_step(params, tokens, cache)
    return decode_step
