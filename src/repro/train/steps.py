"""jit-able train / eval / decode steps shared by the trainer, the serving
engine, and the multi-pod dry-run.

`make_train_step(lm, ...)` returns a pure function
    (state, batch) -> (state, metrics)
with loss+grad under remat, global-norm clipping, AdamW, and the paper's LR
schedule; everything pjit-shards via the in/out shardings the caller derives
from `repro.sharding.rules`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import LM
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         warmup_step_decay)

PyTree = Any


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt: PyTree
    step: jax.Array


jax.tree_util.register_pytree_with_keys(
    TrainState,
    lambda s: ((("params", s.params), ("opt", s.opt), ("step", s.step)),
               None),
    lambda aux, c: TrainState(*c))


def init_train_state(lm: LM, key: jax.Array) -> TrainState:
    params = lm.init(key)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def abstract_train_state(lm: LM) -> TrainState:
    """ShapeDtypeStruct TrainState (no allocation) for AOT lowering."""
    params = lm.abstract_params()
    opt = jax.eval_shape(adamw_init, params)
    return TrainState(params=params, opt=opt,
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def train_state_axes(lm: LM) -> TrainState:
    """Logical-axes TrainState matching abstract_train_state (moments share
    the param sharding; step is replicated)."""
    axes = lm.logical_axes()
    return TrainState(
        params=axes,
        opt={"m": axes, "v": axes, "step": ()},
        step=())


def make_train_step(lm: LM, *, opt_cfg: AdamWConfig = AdamWConfig(),
                    lr_fn: Optional[Callable] = None, remat: str = "block",
                    microbatch: int = 1, scan_layers: bool = True,
                    scan_microbatches: bool = True
                    ) -> Callable[[TrainState, Dict[str, jax.Array]],
                                  Tuple[TrainState, Dict[str, jax.Array]]]:
    """scan_microbatches=False unrolls the grad-accumulation loop — used by
    the roofline cost probes (XLA cost_analysis counts a scanned microbatch
    body once regardless of trip count)."""
    lr_fn = lr_fn or (lambda s: warmup_step_decay(s))

    def loss_fn(params, batch):
        return lm.loss(params, batch, remat=remat, scan_layers=scan_layers)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatch > 1:
            # gradient accumulation over leading micro-slices of the batch
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), metrics

            mb_batch = jax.tree.map(
                lambda x: x.reshape((microbatch, x.shape[0] // microbatch)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            if scan_microbatches:
                (grads, loss_sum), metrics = jax.lax.scan(
                    micro, (zeros, jnp.zeros((), jnp.float32)), mb_batch)
                metrics = jax.tree.map(lambda m: m[-1], metrics)
            else:
                carry = (zeros, jnp.zeros((), jnp.float32))
                for i in range(microbatch):
                    carry, metrics = micro(
                        carry, jax.tree.map(lambda x: x[i], mb_batch))
                grads, loss_sum = carry
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = loss_sum / microbatch
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        lr = lr_fn(state.step)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, lr, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["lr"] = lr
        metrics["loss"] = loss
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1)
        return new_state, metrics

    return train_step


def make_eval_step(lm: LM) -> Callable:
    def eval_step(params, batch):
        _, metrics = lm.loss(params, batch, remat="none")
        return metrics
    return eval_step


def make_decode_step(lm: LM) -> Callable:
    def decode_step(params, tokens, cache):
        return lm.decode_step(params, tokens, cache)
    return decode_step
