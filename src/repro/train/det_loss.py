"""YOLOv2-style detection loss + VOC mAP@0.5 evaluation (paper Sec. V)."""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.detection import ANCHORS


def decode_head(pred: jax.Array, n_anchors: int, n_classes: int):
    """[B,gh,gw,A*(5+C)] -> dict of txy/twh/obj/cls tensors."""
    B, gh, gw, _ = pred.shape
    p = pred.reshape(B, gh, gw, n_anchors, 5 + n_classes)
    return {
        "txy": jax.nn.sigmoid(p[..., 0:2]),
        "twh": p[..., 2:4],
        "obj": p[..., 4],
        "cls": p[..., 5:],
    }


def yolo_loss(pred: jax.Array, targets: Dict[str, jax.Array],
              n_anchors: int, n_classes: int,
              lambda_coord: float = 5.0, lambda_noobj: float = 0.5
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    d = decode_head(pred, n_anchors, n_classes)
    obj_t = targets["obj"]                    # [B,gh,gw,A]
    xywh_t = targets["txywh"]                 # [B,gh,gw,A,4]
    cls_t = targets["cls"]                    # [B,gh,gw,A]

    anchors = jnp.asarray(ANCHORS[:n_anchors])        # [A,2]
    wh_pred = anchors * jnp.exp(jnp.clip(d["twh"], -4.0, 4.0))
    xy_loss = jnp.sum(jnp.square(d["txy"] - xywh_t[..., 0:2]), -1)
    wh_loss = jnp.sum(jnp.square(jnp.sqrt(wh_pred + 1e-9)
                                 - jnp.sqrt(xywh_t[..., 2:4] + 1e-9)), -1)
    coord = lambda_coord * jnp.sum(obj_t * (xy_loss + wh_loss))

    obj_logit = d["obj"]
    bce = jnp.maximum(obj_logit, 0) - obj_logit * obj_t + \
        jnp.log1p(jnp.exp(-jnp.abs(obj_logit)))
    obj_loss = jnp.sum(obj_t * bce) + lambda_noobj * jnp.sum((1 - obj_t) * bce)

    logp = jax.nn.log_softmax(d["cls"], axis=-1)
    cls_nll = -jnp.take_along_axis(logp, cls_t[..., None], axis=-1)[..., 0]
    cls_loss = jnp.sum(obj_t * cls_nll)

    n_pos = jnp.maximum(jnp.sum(obj_t), 1.0)
    total = (coord + obj_loss + cls_loss) / n_pos
    return total, {"coord": coord / n_pos, "obj": obj_loss / n_pos,
                   "cls": cls_loss / n_pos}


# ------------------------------------------------------------------ mAP

def _decode_boxes(pred: np.ndarray, n_anchors: int, n_classes: int,
                  conf_thresh: float = 0.1):
    """One image's head output -> (boxes [n,4] cx cy w h, scores, classes)."""
    gh, gw, _ = pred.shape
    p = pred.reshape(gh, gw, n_anchors, 5 + n_classes)
    txy = 1 / (1 + np.exp(-p[..., 0:2]))
    twh = np.clip(p[..., 2:4], -4, 4)
    wh = ANCHORS[:n_anchors] * np.exp(twh)
    obj = 1 / (1 + np.exp(-p[..., 4]))
    cls_prob = np.exp(p[..., 5:] - p[..., 5:].max(-1, keepdims=True))
    cls_prob /= cls_prob.sum(-1, keepdims=True)
    gy, gx = np.meshgrid(np.arange(gh), np.arange(gw), indexing="ij")
    cx = (gx[..., None] + txy[..., 0]) / gw
    cy = (gy[..., None] + txy[..., 1]) / gh
    conf = obj[..., None] * cls_prob
    boxes, scores, classes = [], [], []
    for c in range(n_classes):
        m = conf[..., c] > conf_thresh
        if not m.any():
            continue
        boxes.append(np.stack([cx[m], cy[m], wh[..., 0][m], wh[..., 1][m]], -1))
        scores.append(conf[..., c][m])
        classes.append(np.full(int(m.sum()), c))
    if not boxes:
        return (np.zeros((0, 4), np.float32), np.zeros(0, np.float32),
                np.zeros(0, np.int64))
    return np.concatenate(boxes), np.concatenate(scores), np.concatenate(classes)


def _iou(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """IoU between [n,4] and [m,4] (cx,cy,w,h)."""
    ax0, ay0 = a[:, 0] - a[:, 2] / 2, a[:, 1] - a[:, 3] / 2
    ax1, ay1 = a[:, 0] + a[:, 2] / 2, a[:, 1] + a[:, 3] / 2
    bx0, by0 = b[:, 0] - b[:, 2] / 2, b[:, 1] - b[:, 3] / 2
    bx1, by1 = b[:, 0] + b[:, 2] / 2, b[:, 1] + b[:, 3] / 2
    ix = np.maximum(0, np.minimum(ax1[:, None], bx1) -
                    np.maximum(ax0[:, None], bx0))
    iy = np.maximum(0, np.minimum(ay1[:, None], by1) -
                    np.maximum(ay0[:, None], by0))
    inter = ix * iy
    area_a = (ax1 - ax0) * (ay1 - ay0)
    area_b = (bx1 - bx0) * (by1 - by0)
    return inter / (area_a[:, None] + area_b - inter + 1e-9)


def _nms(boxes, scores, thresh=0.45):
    order = np.argsort(-scores)
    keep = []
    while order.size:
        i = order[0]
        keep.append(i)
        if order.size == 1:
            break
        ious = _iou(boxes[i:i + 1], boxes[order[1:]])[0]
        order = order[1:][ious < thresh]
    return np.asarray(keep, np.int64)


def decode_detections(pred: np.ndarray, n_anchors: int, n_classes: int,
                      conf_thresh: float = 0.1, nms_thresh: float = 0.45):
    """One image's head output -> per-class-NMS'd detections.

    Returns (boxes [n,4] cx cy w h as image fractions, scores, classes),
    sorted by descending score — the same decode + suppression `evaluate_map`
    applies before AP matching, exposed for callers that want the boxes
    themselves (the serving engine's response payload)."""
    boxes, scores, classes = _decode_boxes(pred, n_anchors, n_classes,
                                           conf_thresh)
    keep_parts = []
    for c in np.unique(classes):
        idx = np.nonzero(classes == c)[0]
        keep_parts.append(idx[_nms(boxes[idx], scores[idx], nms_thresh)])
    if not keep_parts:
        return boxes, scores, classes                 # already empty
    keep = np.concatenate(keep_parts)
    keep = keep[np.argsort(-scores[keep])]
    return boxes[keep], scores[keep], classes[keep]


def evaluate_map(preds: np.ndarray, gt_boxes: List[np.ndarray],
                 gt_classes: List[np.ndarray], n_anchors: int,
                 n_classes: int, iou_thresh: float = 0.5) -> float:
    """VOC-style mAP@0.5 over a batch of head outputs."""
    det = {c: [] for c in range(n_classes)}   # (score, img, box)
    n_gt = {c: 0 for c in range(n_classes)}
    for c_list in gt_classes:
        for c in c_list:
            n_gt[int(c)] += 1
    for i, pred in enumerate(preds):
        boxes, scores, classes = _decode_boxes(pred, n_anchors, n_classes)
        for c in range(n_classes):
            m = classes == c
            if not m.any():
                continue
            b, s = boxes[m], scores[m]
            keep = _nms(b, s)
            for k in keep:
                det[c].append((float(s[k]), i, b[k]))
    aps = []
    for c in range(n_classes):
        if n_gt[c] == 0:
            continue
        entries = sorted(det[c], key=lambda e: -e[0])
        matched = [np.zeros(len(gb), bool) for gb in gt_boxes]
        tp = np.zeros(len(entries))
        fp = np.zeros(len(entries))
        for j, (score, img, box) in enumerate(entries):
            gmask = gt_classes[img] == c
            if not gmask.any():
                fp[j] = 1
                continue
            gb = gt_boxes[img][gmask]
            ious = _iou(box[None], gb)[0]
            best = int(np.argmax(ious))
            gidx = np.where(gmask)[0][best]
            if ious[best] >= iou_thresh and not matched[img][gidx]:
                tp[j] = 1
                matched[img][gidx] = True
            else:
                fp[j] = 1
        ctp, cfp = np.cumsum(tp), np.cumsum(fp)
        recall = ctp / n_gt[c]
        precision = ctp / np.maximum(ctp + cfp, 1e-9)
        ap = 0.0
        for r in np.linspace(0, 1, 11):
            p = precision[recall >= r].max() if (recall >= r).any() else 0.0
            ap += p / 11
        aps.append(ap)
    return float(np.mean(aps)) if aps else 0.0


def evaluate_map_per_chip(preds, gt_boxes: List[np.ndarray],
                          gt_classes: List[np.ndarray], n_anchors: int,
                          n_classes: int, iou_thresh: float = 0.5
                          ) -> np.ndarray:
    """[chips, B, gh, gw, A*(5+C)] head outputs -> [chips] mAP@0.5.

    The host-side metric callback of the chip-ensemble MC engine: NMS and AP
    are not array programs, so each chunk's predictions come back to the host
    and every chip's mAP folds into the streaming Welford/quantile
    accumulators (Table II's actual metric over a chip population).
    """
    preds = np.asarray(preds)
    return np.array([evaluate_map(p, gt_boxes, gt_classes, n_anchors,
                                  n_classes, iou_thresh) for p in preds],
                    np.float32)
