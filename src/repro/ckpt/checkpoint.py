"""Fault-tolerant checkpointing (no orbax in this environment — built here).

Design for 1000+ node clusters:
  * SHARDED per host: each host writes only the addressable shards of its
    arrays (`host_<i>.npz`); no host ever materializes the global state.
  * ATOMIC: writes go to `step_<n>.tmp/` and are renamed to `step_<n>/`
    only after all hosts' files + metadata are fsynced — a job killed
    mid-save can never leave a half checkpoint that restore would pick up.
  * ASYNC: `save_async` snapshots to host RAM (device_get) and writes on a
    background thread; training continues immediately.
  * KEEP-K: old steps are garbage-collected after a successful save.
  * ELASTIC restore: arrays are re-device_put against the CURRENT mesh
    shardings, so a job restarted on a different topology (node failure,
    pool resize) resumes from the same global state.

Pytree leaves are addressed by their flattened key-path string, making the
format stable across minor code refactors.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten_with_paths(tree: PyTree) -> Dict[str, Any]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out[_path_str(path)] = leaf
    return out


def save_pytree(tree: PyTree, directory: str | Path, step: int,
                host_id: int = 0, n_hosts: int = 1) -> Path:
    """Synchronous sharded save with atomic rename."""
    directory = Path(directory)
    tmp = directory / f"step_{step:09d}.tmp"
    final = directory / f"step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    dtypes = {k: str(v.dtype) for k, v in arrays.items()}
    # npz can't serialize ml_dtypes (bfloat16 etc., numpy kind 'V') —
    # store their raw bit pattern as unsigned ints; META records the dtype
    storable = {
        k: (v if v.dtype.kind in "fiub"
            else v.view({1: np.uint8, 2: np.uint16,
                         4: np.uint32}[v.dtype.itemsize]))
        for k, v in arrays.items()
    }
    np.savez(tmp / f"host_{host_id}.npz", **storable)
    meta = {
        "step": step, "n_hosts": n_hosts,
        "time": time.time(),
        "keys": sorted(arrays),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": dtypes,
    }
    (tmp / "META.json").write_text(json.dumps(meta))
    # fsync the directory entries, then atomic rename
    for f in tmp.iterdir():
        fd = os.open(f, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and not p.name.endswith(".tmp")
             and (p / "META.json").exists()]
    return max(steps) if steps else None


def restore_pytree(template: PyTree, directory: str | Path,
                   step: Optional[int] = None, host_id: int = 0,
                   shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of `template`; if `shardings` is given the
    arrays are device_put against it (elastic reshard on a new mesh)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    src = directory / f"step_{step:09d}"
    data = np.load(src / f"host_{host_id}.npz")
    meta = json.loads((src / "META.json").read_text())
    flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(flat_template))
    for (path, tmpl), sh in zip(flat_template, sh_leaves):
        key = _path_str(path)
        arr = data[key]
        saved_dtype = meta["dtypes"].get(key, str(arr.dtype))
        if saved_dtype != str(arr.dtype):       # bit-pattern stored dtype
            import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)
            arr = arr.view(np.dtype(saved_dtype))
        arr = arr.astype(tmpl.dtype) if hasattr(tmpl, "dtype") else arr
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Async keep-k checkpoint manager."""

    def __init__(self, directory: str | Path, keep: int = 3,
                 host_id: int = 0, n_hosts: int = 1):
        self.directory = Path(directory)
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: list = []

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, tree: PyTree, step: int):
        """Snapshot to host RAM now; write + GC on a background thread."""
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save_pytree(snapshot, self.directory, step, self.host_id,
                        self.n_hosts)
            self.saved_steps.append(step)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def save(self, tree: PyTree, step: int):
        save_pytree(tree, self.directory, step, self.host_id, self.n_hosts)
        self.saved_steps.append(step)
        self._gc()

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        doomed = steps[:-self.keep] if self.keep else []
        for s in doomed:
            shutil.rmtree(self.directory / f"step_{s:09d}", ignore_errors=True)

    def restore_latest(self, template: PyTree, shardings=None) -> tuple:
        step = latest_step(self.directory)
        if step is None:
            return None, None
        tree = restore_pytree(template, self.directory, step, self.host_id,
                              shardings)
        return tree, step
