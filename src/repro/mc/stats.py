"""Streaming ensemble statistics: Welford moments + exact quantiles.

The MC engine evaluates chips in chunks so the [chips, batch, n_out]
activation tensor never materializes for the whole ensemble; what survives a
chunk is (a) the running Welford state of every tracked metric and (b) the
per-chip SCALAR metric values (a few bytes per chip, kept for exact
quantiles and for determinism tests).  Welford/Chan merging makes the
mean/std independent of chunking up to float round-off — covered by
tests/test_mc.py against a one-shot jnp computation at 1e-6.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class Welford(NamedTuple):
    """Running (count, mean, M2) triplet; elementwise over `mean.shape`."""
    count: jax.Array
    mean: jax.Array
    m2: jax.Array


def welford_init(shape=()) -> Welford:
    """Empty running state (count/mean/M2 all zero) of the given shape."""
    z = jnp.zeros(shape, jnp.float32)
    return Welford(count=jnp.zeros(shape, jnp.float32), mean=z, m2=z)


def welford_merge(a: Welford, b: Welford) -> Welford:
    """Chan parallel combination of two Welford states."""
    n = a.count + b.count
    safe_n = jnp.maximum(n, 1.0)
    delta = b.mean - a.mean
    mean = a.mean + delta * b.count / safe_n
    m2 = a.m2 + b.m2 + delta * delta * a.count * b.count / safe_n
    return Welford(count=n, mean=mean, m2=m2)


def welford_add_batch(state: Welford, xs: jax.Array, axis: int = 0) -> Welford:
    """Fold a batch of samples (along `axis`) into the running state."""
    xs = xs.astype(jnp.float32)
    n = jnp.full(state.count.shape, xs.shape[axis], jnp.float32)
    mean = jnp.mean(xs, axis=axis)
    m2 = jnp.sum(jnp.square(xs - jnp.expand_dims(mean, axis)), axis=axis)
    return welford_merge(state, Welford(count=n, mean=mean, m2=m2))


def welford_finalize(state: Welford) -> Dict[str, jax.Array]:
    """Population mean/std (ddof=0, matching jnp defaults)."""
    var = state.m2 / jnp.maximum(state.count, 1.0)
    return {"count": state.count, "mean": state.mean,
            "std": jnp.sqrt(jnp.maximum(var, 0.0))}


DEFAULT_QUANTILES = (0.05, 0.25, 0.5, 0.75, 0.95)


@dataclasses.dataclass
class StreamingMoments:
    """Host-side accumulator for one scalar metric over the chip ensemble.

    Bounded memory: the Welford state is O(1) and the retained per-chip
    values are scalars (exact quantiles over hundreds-to-thousands of chips
    cost a few KB; a P2-style approximation would buy nothing here).
    """
    quantiles: Sequence[float] = DEFAULT_QUANTILES

    def __post_init__(self):
        self._state = welford_init()
        self._values: list = []

    def update(self, chunk_values: jax.Array) -> None:
        """Fold a [chunk_chips] vector of per-chip metric values."""
        chunk_values = jnp.ravel(chunk_values)
        self._state = welford_add_batch(self._state, chunk_values)
        self._values.append(np.asarray(chunk_values))

    @property
    def per_chip(self) -> np.ndarray:
        """All folded per-chip values, concatenated in arrival order."""
        return (np.concatenate(self._values) if self._values
                else np.zeros((0,), np.float32))

    @property
    def count(self) -> float:
        """Chips folded in so far."""
        return float(self._state.count)

    @property
    def mean_value(self) -> float:
        """Running population mean of the metric."""
        return float(self._state.mean)

    def stderr(self) -> float:
        """Standard error of the running mean: std/sqrt(count), using the
        same population std (ddof=0) as `summary()`, so convergence targets
        are stated in the units the report itself uses.  inf below 2 chips
        (no spread evidence yet) — the convergence monitor's early stop can
        therefore never fire on a single sample."""
        n = self.count
        if n < 2:
            return float("inf")
        fin = welford_finalize(self._state)
        return float(fin["std"]) / math.sqrt(n)

    def summary(self) -> Dict[str, float]:
        """{count, mean, std (ddof=0), qXX...} over the folded chips — the
        population-statistics dict reported per metric (and per serving
        response) across the repo."""
        fin = welford_finalize(self._state)
        out = {"count": float(fin["count"]), "mean": float(fin["mean"]),
               "std": float(fin["std"])}
        vals = self.per_chip
        if vals.size:
            qs = np.quantile(vals, np.asarray(self.quantiles, np.float64))
            out.update({f"q{int(round(q * 100)):02d}": float(v)
                        for q, v in zip(self.quantiles, qs)})
        return out
