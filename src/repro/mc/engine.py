"""Chip-ensemble Monte Carlo engine: one jitted computation, many chips.

`ensemble_apply` vmaps the deterministic `crossbar_apply` over the ensemble's
leading chips axis (or dispatches the chip-batched Pallas kernel), so a whole
population of sampled dies is a single XLA program instead of a Python loop
of structural sims.  `run_mc` streams an arbitrarily large ensemble through
it in fixed-size chunks, folding per-chip metrics into Welford/quantile
accumulators so memory stays bounded by `chunk_size`, and `run_ablation`
sweeps the Table-II effect toggles to produce mean±std columns.

Chunking is statistically invisible: chip `c` is keyed by `fold_in(key, c)`
regardless of which chunk evaluates it, so `chunk_size` only trades memory
for launch count (tests assert identical per-chip metrics across chunkings).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.macro import MacroSpec, DEFAULT_MACRO
from repro.core import nonideal as ni
from repro.core.crossbar import crossbar_apply, _block_reduce, _accumulate
from repro.mc.ensemble import ChipEnsemble, sample_ensemble, \
    calibrate_ensemble_bias, shard_ensemble
from repro.mc.stats import StreamingMoments, DEFAULT_QUANTILES
from repro.obs import ConvergenceMonitor, PhaseTimer, RunLog, as_runlog


# ------------------------------------------------------------------ forward

def _extend(x_bits: jax.Array, lead_rows: int) -> jax.Array:
    x = x_bits.astype(jnp.float32)
    if lead_rows == 0:
        return x
    ones = jnp.ones(x.shape[:-1] + (lead_rows,), jnp.float32)
    return jnp.concatenate([ones, x], axis=-1)


@functools.partial(jax.jit, static_argnames=("cfg", "spec", "accumulation",
                                             "partial_rows", "sa_extra_units",
                                             "output", "per_chip_x", "device"))
def ensemble_apply(ens: ChipEnsemble, x_bits: jax.Array, *,
                   cfg: ni.NonidealConfig, spec: MacroSpec = DEFAULT_MACRO,
                   accumulation: str = "single_shot", partial_rows: int = 256,
                   sa_extra_units: float = 0.0,
                   output: str = "binary",
                   per_chip_x: bool = False, device=None) -> jax.Array:
    """Evaluate every chip on a shared input batch: [chips, batch, n_out].

    Chip `c`'s slice equals `crossbar_forward(fold_in(key, c), x, mapped, ...)`
    bit-for-bit (same key-split discipline; tests/test_mc.py pins this).

    When the LRS placement planes are shared by all chips, the activated-count
    block dots are hoisted OUT of the chips vmap — counts are sums of {0,1}
    products, exact in f32 at any summation order, so sharing them across the
    ensemble halves the matmul work without changing a single output bit.

    With `per_chip_x`, x_bits carries a leading chips axis ([chips, batch,
    fan_in]) — how network-level MC feeds chip-diverged activations from one
    IRC layer into the next.  Counts then depend on each chip's own inputs,
    so nothing hoists, but the placement planes still pass through as ONE
    shared [rows, n_out] array.

    `device` is the `repro.device` backend for the PERIPHERY terms (SA
    offset sigma, IR drop); it must match the backend the ensemble's planes
    were sampled with.  Device models are frozen hashable dataclasses, so
    passing one as a static argument reuses the jit cache across calls.
    """
    x_ext = _extend(x_bits, ens.lead_rows)
    if per_chip_x:
        assert x_bits.ndim >= 3 and x_bits.shape[0] == ens.n_chips, (
            f"per_chip_x needs [chips={ens.n_chips}, ..., fan_in] inputs, "
            f"got {x_bits.shape}")
        in_g = 0 if ens.planes_per_chip() else None
        fwd = lambda k, xc, ep, en, gp, gn: crossbar_apply(
            k, xc, ep, en, gp, gn, cfg=cfg, spec=spec,
            accumulation=accumulation, partial_rows=partial_rows,
            sa_extra_units=sa_extra_units, output=output, device=device)
        return jax.vmap(fwd, in_axes=(0, 0, 0, 0, in_g, in_g))(
            ens.sa_keys, x_ext, ens.ep, ens.en, ens.gp, ens.gn)
    if ens.planes_per_chip():
        fwd = lambda k, ep, en, gp, gn: crossbar_apply(
            k, x_ext, ep, en, gp, gn, cfg=cfg, spec=spec,
            accumulation=accumulation, partial_rows=partial_rows,
            sa_extra_units=sa_extra_units, output=output, device=device)
        return jax.vmap(fwd)(ens.sa_keys, ens.ep, ens.en, ens.gp, ens.gn)

    blk = spec.ir_block
    counts_p = _block_reduce(x_ext, ens.gp, blk)      # chip-independent
    counts_n = _block_reduce(x_ext, ens.gn, blk)

    def fwd(k_sa, ep, en):
        """One chip's forward against the SHARED placement-plane counts."""
        i_pos, p_pos = _accumulate(_block_reduce(x_ext, ep, blk), counts_p,
                                   cfg, spec, accumulation, partial_rows,
                                   device)
        i_neg, p_neg = _accumulate(_block_reduce(x_ext, en, blk), counts_n,
                                   cfg, spec, accumulation, partial_rows,
                                   device)
        if output == "diff":
            return i_pos - i_neg
        if output == "sensed_diff":
            return ni.sensed_diff(k_sa, i_pos, i_neg, p_pos + p_neg, cfg,
                                  spec, sa_extra_units, device)
        return ni.resolve_sa(k_sa, i_pos, i_neg, p_pos + p_neg, cfg, spec,
                             sa_extra_units, device)

    return jax.vmap(fwd)(ens.sa_keys, ens.ep, ens.en)


@functools.partial(jax.jit, static_argnames=("cfg", "spec", "sa_extra_units",
                                             "output", "per_chip_x", "impl",
                                             "bm", "bn", "bk", "device"))
def ensemble_apply_kernel(ens: ChipEnsemble, x_bits: jax.Array, *,
                          cfg: ni.NonidealConfig,
                          spec: MacroSpec = DEFAULT_MACRO,
                          sa_extra_units: float = 0.0, output: str = "binary",
                          per_chip_x: bool = False, impl: str = "pallas",
                          bm: int = 8, bn: int = 128, bk: int = 256,
                          device=None) -> jax.Array:
    """Chip-batched Pallas path: ONE kernel launch services all chips.

    Single-shot accumulation only (the kernel's fused epilogue).  The
    per-read stochastic terms are pre-sampled here from each chip's `sa_keys`
    with the `irc_mvm_from_mapped` key discipline, so chip `c` matches a loop
    of single-chip kernel calls exactly.

    With `per_chip_x`, x_bits carries a leading chips axis ([chips, batch,
    fan_in]) — chip-diverged activations downstream of the first IRC layer;
    the kernel walks a per-chip word-line block instead of reusing one
    shared tile.  `impl` selects the pallas kernel ("pallas", interpret mode
    on CPU) or its pure-jnp oracle ("ref") — the oracle IS the kernel's
    bit-exactness contract (tests pin pallas == ref through the whole
    detector), so routing through it gives kernel-semantics outputs where
    interpret mode would be too slow.
    """
    from repro.kernels.ops import irc_mvm_chips
    from repro.kernels.ref import IrcEpilogueParams, irc_mvm_chips_ref
    if device is not None and not device.analytic_periphery:
        # the Pallas epilogue bakes the ANALYTIC periphery closed forms
        # (g(p) polynomial, linear IR drop) into scalar params; a backend
        # with its own periphery model cannot be expressed in them
        raise NotImplementedError(
            f"device model {device.name!r} has a non-analytic periphery; "
            "the chip-batched kernel supports analytic-periphery backends "
            "only — use the jnp engine (backend='jnp')")
    if per_chip_x:
        assert x_bits.ndim == 3 and x_bits.shape[0] == ens.n_chips, (
            f"per_chip_x needs [chips={ens.n_chips}, batch, fan_in] inputs, "
            f"got {x_bits.shape}")
    x_ext = _extend(x_bits, ens.lead_rows)
    B, N = x_ext.shape[-2], ens.n_out

    def periphery(k_sa):
        """Per-chip SA offsets + comparator tie-break draws (key-split once)."""
        k_off, k_rng = jax.random.split(k_sa)
        return (jax.random.normal(k_off, (B, N), jnp.float32),
                jax.random.bernoulli(k_rng, 0.5, (B, N)).astype(jnp.float32))

    eps_sa, rnd = jax.vmap(periphery)(ens.sa_keys)
    # shared placement planes pass through as [R, N]: the kernel's count
    # BlockSpec ignores the chip coordinate, so one HBM copy serves all chips
    gp, gn = ens.gp, ens.gn
    params = IrcEpilogueParams.from_macro(
        spec, sa_extra=sa_extra_units, output=output,
        apply_nonlinearity=cfg.nonlinearity, apply_ir=cfg.ir_drop,
        apply_sa=cfg.sa_variation, apply_range=cfg.sensing_range)
    if impl == "ref":
        return irc_mvm_chips_ref(x_ext, ens.ep, ens.en, gp, gn, eps_sa, rnd,
                                 params)
    return irc_mvm_chips(x_ext, ens.ep, ens.en, gp, gn, eps_sa, rnd, params,
                         bm=bm, bn=bn, bk=bk)


@functools.partial(jax.jit, static_argnames=("scheme", "fan_in", "cfg",
                                             "spec", "accumulation",
                                             "partial_rows", "sa_extra_units",
                                             "backend", "device"),
                   donate_argnums=(0, 1, 2))
def _ensemble_apply_donated(ep, en, sa_keys, chip_ids, gp, gn, bias_units,
                            x_bits, *, scheme, fan_in, cfg, spec,
                            accumulation, partial_rows, sa_extra_units,
                            backend, device=None):
    """Per-chunk forward with the chunk's THROWAWAY sampled state donated.

    `run_mc` samples fresh ep/en/sa_keys every chunk and never touches them
    after the forward, so donating them lets XLA reuse those buffers for the
    chunk's activations instead of allocating a second ensemble-sized block
    — on accelerators this halves the peak footprint of the streaming loop
    (CPU accepts the donation too).  The placement planes and word-line bits
    are NOT donated: `mapped.g_pos` / `x_bits` are shared by every chunk.
    """
    ens = ChipEnsemble(ep=ep, en=en, gp=gp, gn=gn, sa_keys=sa_keys,
                       chip_ids=chip_ids, bias_units=bias_units,
                       scheme=scheme, fan_in=fan_in)
    if backend == "kernel":
        return ensemble_apply_kernel(ens, x_bits, cfg=cfg, spec=spec,
                                     sa_extra_units=sa_extra_units,
                                     device=device)
    return ensemble_apply(ens, x_bits, cfg=cfg, spec=spec,
                          accumulation=accumulation,
                          partial_rows=partial_rows,
                          sa_extra_units=sa_extra_units, device=device)


# ------------------------------------------------------------------ metrics

MetricFn = Callable[[jax.Array], jax.Array]   # [chips, B, N] -> [chips]


def bit_agreement_metric(ref_bits: jax.Array) -> MetricFn:
    """Fraction of SA decisions agreeing with the ideal digital output —
    the accuracy/mAP-drop proxy used across the benchmark suite."""
    ref = (ref_bits > 0.5).astype(jnp.float32)
    return lambda out: jnp.mean((out > 0.5).astype(jnp.float32) == ref,
                                axis=(-2, -1))


def ones_fraction_metric() -> MetricFn:
    """Per-chip fraction of 1-bits in the output — a cheap drift indicator
    (a chip whose comparators saturate shows up before accuracy is scored)."""
    return lambda out: jnp.mean(out, axis=(-2, -1))


@functools.partial(jax.jit, static_argnames=("scheme", "fan_in", "cfg",
                                             "spec", "accumulation",
                                             "partial_rows", "sa_extra_units",
                                             "device"))
def _fused_chunk_metrics(key, ids, x_bits, gp, gn, ref_bits, *, scheme,
                         fan_in, cfg, spec, accumulation, partial_rows,
                         sa_extra_units, device=None):
    """sample -> forward -> per-chip metrics as one cached jitted program
    (module-level so repeated `run_mc` calls reuse the compilation; eager
    per-chunk sampling and op-by-op metric reductions otherwise cost as much
    as the forward itself on small chunks)."""
    from repro.core.mapping import MappedLayer
    mapped = MappedLayer(g_pos=gp, g_neg=gn,
                         bias_rows=gp.shape[0] - fan_in, scheme=scheme,
                         fan_in=fan_in)
    ens = sample_ensemble(key, mapped, chip_ids=ids, cfg=cfg, spec=spec,
                          device=device)
    out = ensemble_apply(ens, x_bits, cfg=cfg, spec=spec,
                         accumulation=accumulation,
                         partial_rows=partial_rows,
                         sa_extra_units=sa_extra_units, device=device)
    metrics = {"ones_fraction": ones_fraction_metric()(out)}
    if ref_bits is not None:
        metrics["bit_agreement"] = bit_agreement_metric(ref_bits)(out)
    return metrics


# ------------------------------------------------------------------ MC sweep

@dataclasses.dataclass(frozen=True)
class McConfig:
    """One ensemble sweep: population size, chunking, effect toggles.

    `device` is the `repro.device` backend chips are sampled from and the
    periphery statistics come from (None: analytic — the paper's closed
    forms, bit-identical to the pre-seam engine); build named/aged backends
    with `repro.device.get_device_model`.
    """
    n_chips: int = 64
    chunk_size: int = 32
    cfg: ni.NonidealConfig = ni.NonidealConfig.all()
    accumulation: str = "single_shot"
    partial_rows: int = 256
    sa_extra_units: float = 0.0
    backend: str = "jnp"                 # "jnp" | "kernel"
    calibrate: bool = False              # per-chip bias calibration
    quantiles: Tuple[float, ...] = DEFAULT_QUANTILES
    device: Optional[object] = None      # repro.device.DeviceModel


@dataclasses.dataclass
class McResult:
    """Ensemble statistics for one sweep.

    `wall_s` is the whole sweep including the first chunk's trace/compile;
    `compile_s` is that first-chunk wall alone, and `chips_per_sec` is the
    STEADY-STATE rate over the remaining chunks (total-based when the sweep
    ran a single chunk) — at small `n_chips` the old conflated rate was
    dominated by compilation and meaningless as a throughput number.
    With `stderr_target` early stop, `n_chips` is the count actually
    evaluated (a prefix of the requested population).

    `device_s`/`host_s` split the loop body: time BLOCKED waiting on device
    results vs. host-side metric work (mAP matching, numpy transfers).  In a
    pipelined sweep the next chunk runs on device DURING the host slice, so
    blocked time collapses; `1 - device_s / wall_s` measures the realized
    overlap (serial loop ~= host fraction; -> 1.0 as device waits are fully
    hidden behind host scoring).
    """
    n_chips: int
    metrics: Dict[str, Dict[str, float]]      # name -> {mean,std,qXX,...}
    per_chip: Dict[str, np.ndarray]           # name -> [n_chips]
    wall_s: float
    chips_per_sec: float
    compile_s: float = 0.0
    bias_units: Optional[np.ndarray] = None   # per-chip calibrated bias
    device_s: float = 0.0                     # blocked-on-device wall
    host_s: float = 0.0                       # host-side metric wall

    def summary_line(self, metric: str = "bit_agreement") -> str:
        """One-line mean±std + quantile report for `metric`, as printed by
        the CLI and the benchmark rows."""
        m = self.metrics[metric]
        qs = ";".join(f"{k}={v:.4f}" for k, v in sorted(m.items())
                      if k.startswith("q"))
        return (f"{metric}={m['mean']:.4f}±{m['std']:.4f} "
                f"({qs}) over {self.n_chips} chips "
                f"[{self.chips_per_sec:.1f} chips/s steady, "
                f"compile {self.compile_s:.2f}s]")


HostMetricFn = Callable[[np.ndarray], np.ndarray]   # [chips,B,N] -> [chips]


def run_mc(key: jax.Array, mapped, x_bits: jax.Array, *,
           ref_bits: Optional[jax.Array] = None,
           mc: McConfig = McConfig(), spec: MacroSpec = DEFAULT_MACRO,
           metric_fns: Optional[Dict[str, MetricFn]] = None,
           host_metric_fns: Optional[Dict[str, HostMetricFn]] = None,
           x_calib_bits: Optional[jax.Array] = None, mesh=None,
           obs: Optional[RunLog] = None,
           stderr_target: Optional[float] = None,
           stderr_metric: Optional[str] = None) -> McResult:
    """Stream an ensemble of `mc.n_chips` sampled chips over `x_bits`.

    Chips are sampled chunk-by-chunk (never materializing more than
    `chunk_size` chips of [rows, n_out] planes or [chunk, B, n_out]
    activations) and their per-chip metrics fold into streaming accumulators.
    `ref_bits` ([B, n_out] ideal binary output) enables the default
    `bit_agreement` metric; pass `metric_fns` for custom on-device
    reductions, or `host_metric_fns` for callbacks that need the chunk's
    outputs on the host (e.g. `evaluate_map` — NMS/AP are not array
    programs); host values fold into the same Welford/quantile accumulators.
    With `mesh`, each chunk's chips axis shards over the data-parallel axes
    (the "chips" rule) — the workload is embarrassingly parallel per chip.

    Observability: pass `obs` (a `repro.obs.RunLog`) to stream per-chunk
    events — raw per-chip metric values (replayable to the reported mean±std
    bit-for-bit) and running count/mean/stderr — into the run directory.
    `stderr_target` stops the sweep at the first chunk boundary where the
    standard error of the mean of every tracked metric (or just
    `stderr_metric`) is at or under the target; because chip `c` is keyed by
    `fold_in(key, c)` regardless of chunking, the early-stopped moments are
    bit-identical to the same-length prefix of the full run.
    """
    obs = as_runlog(obs)
    fns: Dict[str, MetricFn] = {}
    if ref_bits is not None:
        fns["bit_agreement"] = bit_agreement_metric(ref_bits)
    fns["ones_fraction"] = ones_fraction_metric()
    if metric_fns:
        fns.update(metric_fns)
    host_fns: Dict[str, HostMetricFn] = dict(host_metric_fns or {})
    moments = {name: StreamingMoments(mc.quantiles)
               for name in (*fns, *host_fns)}
    bias_chunks: List[np.ndarray] = []

    if mc.backend == "kernel" and mc.accumulation != "single_shot":
        raise ValueError("kernel backend fuses the single-shot path only")

    # Fast path: default metrics, no calibration/sharding -> the cached
    # fused chunk program.  Calibration (host loop), explicit sharding,
    # custom/host metrics and the kernel backend keep the step-by-step path.
    use_fused = (not mc.calibrate and mesh is None and mc.backend == "jnp"
                 and not metric_fns and not host_fns)

    monitor = ConvergenceMonitor(moments, stderr_target=stderr_target,
                                 stderr_metric=stderr_metric, runlog=obs)
    timer = PhaseTimer("mc_chunks", unit="chips")
    obs.log_event("mc_start", n_chips=mc.n_chips, chunk_size=mc.chunk_size,
                  backend=mc.backend, calibrate=mc.calibrate,
                  fused=use_fused, stderr_target=stderr_target,
                  device_model=(mc.device.name if mc.device is not None
                                else "analytic"))

    n_done = 0
    for chunk_i, lo in enumerate(range(0, mc.n_chips, mc.chunk_size)):
        ids = jnp.arange(lo, min(lo + mc.chunk_size, mc.n_chips),
                         dtype=jnp.uint32)
        with timer.lap(items=int(ids.shape[0])):
            if use_fused:
                chunk_vals = dict(jax.block_until_ready(_fused_chunk_metrics(
                    key, ids, x_bits, mapped.g_pos, mapped.g_neg, ref_bits,
                    scheme=mapped.scheme, fan_in=mapped.fan_in, cfg=mc.cfg,
                    spec=spec, accumulation=mc.accumulation,
                    partial_rows=mc.partial_rows,
                    sa_extra_units=mc.sa_extra_units, device=mc.device)))
            else:
                ens = sample_ensemble(key, mapped, chip_ids=ids, cfg=mc.cfg,
                                      spec=spec, device=mc.device)
                if mc.calibrate:
                    ens = calibrate_ensemble_bias(
                        ens, x_bits if x_calib_bits is None else x_calib_bits,
                        spec, device=mc.device)
                    bias_chunks.append(np.asarray(ens.bias_units))
                if mesh is not None:
                    ens = shard_ensemble(ens, mesh)
                # ep/en/sa_keys are this chunk's throwaway sampled state —
                # donated so the forward can recycle their buffers
                out = _ensemble_apply_donated(
                    ens.ep, ens.en, ens.sa_keys, ens.chip_ids, ens.gp,
                    ens.gn, ens.bias_units, x_bits, scheme=ens.scheme,
                    fan_in=ens.fan_in, cfg=mc.cfg, spec=spec,
                    accumulation=mc.accumulation,
                    partial_rows=mc.partial_rows,
                    sa_extra_units=mc.sa_extra_units, backend=mc.backend,
                    device=mc.device)
                out = jax.block_until_ready(out)
                chunk_vals = {name: fn(out) for name, fn in fns.items()}
                if host_fns:
                    out_np = np.asarray(out)
                    for name, fn in host_fns.items():
                        chunk_vals[name] = jnp.asarray(fn(out_np))
        n_done += int(ids.shape[0])
        for name, v in chunk_vals.items():
            moments[name].update(v)
        # the raw per-chip values are the replay evidence: folding them back
        # through StreamingMoments in file order reproduces the reported
        # mean±std bit-for-bit (tests/test_obs.py)
        obs.log_event("chunk", phase="mc", chunk=chunk_i, chip_lo=lo,
                      chips=n_done, wall_s=timer.last_s,
                      values={name: np.asarray(jnp.ravel(v))
                              for name, v in chunk_vals.items()})
        if monitor.after_chunk(chunk_i, n_done):
            obs.log_event("early_stop", chips=n_done, requested=mc.n_chips,
                          stderr_target=stderr_target)
            break

    res = McResult(
        n_chips=n_done,
        metrics={name: m.summary() for name, m in moments.items()},
        per_chip={name: m.per_chip for name, m in moments.items()},
        wall_s=timer.total_s, chips_per_sec=timer.rate(),
        compile_s=timer.compile_s,
        bias_units=(np.concatenate(bias_chunks) if bias_chunks else None))
    obs.log_event("mc_result", chips=n_done, requested=mc.n_chips,
                  wall_s=res.wall_s, compile_s=res.compile_s,
                  chips_per_sec=res.chips_per_sec, metrics=res.metrics)
    return res


# ------------------------------------------------------------------ ablation

# Table II columns: effects switch on cumulatively, plus the all-on row.
TABLE2_ABLATION: Tuple[Tuple[str, ni.NonidealConfig], ...] = (
    ("ideal", ni.NonidealConfig.none()),
    ("devvar", ni.NonidealConfig(device_variation=True)),
    ("devvar+nl", ni.NonidealConfig(device_variation=True, nonlinearity=True)),
    ("devvar+nl+peri", ni.NonidealConfig(device_variation=True,
                                         nonlinearity=True, sa_variation=True,
                                         sensing_range=True)),
    ("all", ni.NonidealConfig.all()),
)


def run_ablation(key: jax.Array, mapped, x_bits: jax.Array, *,
                 ref_bits: jax.Array,
                 ablations: Sequence[Tuple[str, ni.NonidealConfig]]
                 = TABLE2_ABLATION,
                 mc: McConfig = McConfig(), spec: MacroSpec = DEFAULT_MACRO,
                 host_metric_fns: Optional[Dict[str, HostMetricFn]] = None,
                 obs: Optional[RunLog] = None,
                 stderr_target: Optional[float] = None
                 ) -> Dict[str, McResult]:
    """Per-effect ensemble sweep: one `run_mc` per Table-II column, same
    chip key stream (each effect set resamples the same dies' variation)."""
    obs = as_runlog(obs)
    results = {}
    for name, cfg in ablations:
        obs.log_event("ablation_column", phase="mc", column=name)
        results[name] = run_mc(key, mapped, x_bits, ref_bits=ref_bits,
                               mc=dataclasses.replace(mc, cfg=cfg), spec=spec,
                               host_metric_fns=host_metric_fns, obs=obs,
                               stderr_target=stderr_target)
    return results
