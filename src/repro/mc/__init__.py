"""repro.mc — chip-ensemble Monte Carlo evaluation engine.

The paper's reliability numbers are statistics over sampled chip instances;
this package evaluates a population of dies as ONE array program:

  ChipEnsemble / sample_ensemble   pre-sampled per-chip nonideal state with a
                                   leading `chips` axis (fold_in key stream)
  calibrate_ensemble_bias          per-die extra-bias calibration (Table I)
  ensemble_apply                   vmapped structural sim over all chips
  ensemble_apply_kernel            chip-batched fused Pallas launch
  run_mc / run_ablation            streaming Welford/quantile sweeps
                                   (Table II mean±std columns)
  DetectorEnsemble /               whole-network MC: chip populations of the
  run_mc_detector                  detector, metric = host-side mAP@0.5

CLI: `python -m repro.launch.mc` (`--network detector` for whole-network
mAP sweeps); perf: `benchmarks/mc_bench.py`.
"""
from repro.mc.ensemble import (ChipEnsemble, sample_ensemble,
                               sample_ensemble_with_keys, chip_keys,
                               calibrate_ensemble_bias, shard_ensemble,
                               deviation_planes)
from repro.mc.engine import (McConfig, McResult, ensemble_apply,
                             ensemble_apply_kernel, run_mc, run_ablation,
                             bit_agreement_metric, ones_fraction_metric,
                             TABLE2_ABLATION)
from repro.mc.detector_mc import (DetectorEnsemble, build_detector_ensemble,
                                  build_train_ensemble, detector_layer_keys,
                                  detector_planes, committee_wave_forward,
                                  run_mc_detector, run_ablation_detector)
from repro.mc.stats import (Welford, welford_init, welford_merge,
                            welford_add_batch, welford_finalize,
                            StreamingMoments, DEFAULT_QUANTILES)
