"""ChipEnsemble — pre-sampled per-chip nonideal state with a leading chips axis.

The paper's robustness claims are statistics over a *population* of dies:
each fabricated chip freezes one draw of the log-normal device variation and
one SA-offset realization, and mAP numbers are means over sampled chips
(Table II / Figs. 10-12).  `ChipEnsemble` makes that population a first-class
array program: chip `c` of `sample_ensemble(key, ...)` carries EXACTLY the
state that `crossbar_forward(jax.random.fold_in(key, c), ...)` would sample,
stacked as a leading `chips` axis so one vmapped/jitted computation (or one
chip-batched Pallas launch) services the whole ensemble.

Optional per-chip bias calibration (`calibrate_ensemble_bias`) reproduces the
paper's Sec. IV-B.4 deployment flow per die: every chip's own variation draw
yields its own bit-line current distribution, hence its own best extra-bias
row count from `repro.core.calibration.calibrate_bias`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.macro import MacroSpec, DEFAULT_MACRO
from repro.core import nonideal as ni
from repro.core.mapping import MappedLayer
from repro.core.crossbar import sample_chip_planes, _block_reduce, _accumulate
from repro.core.calibration import calibrate_bias


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ChipEnsemble:
    """A population of sampled chip instances for one mapped layer.

    ep/en:    [chips, rows, n_out] effective conductance planes (per-cell
              variation + HRS leak applied; chip identity lives here).
    gp/gn:    binary LRS placement planes — [rows, n_out] when shared by all
              chips (the common case) or [chips, rows, n_out] after per-chip
              bias calibration masks different bias rows per die.
    sa_keys:  [chips, 2] raw PRNG keys seeding each chip's per-read
              peripheral noise (SA offset draws, sensing-range fallback).
    chip_ids: [chips] global chip indices (fold_in stream positions), so a
              chunked sweep over one logical ensemble stays deterministic.
    bias_units: [chips] calibrated active bias rows per chip (or None).
    """
    ep: jax.Array
    en: jax.Array
    gp: jax.Array
    gn: jax.Array
    sa_keys: jax.Array
    chip_ids: jax.Array
    bias_units: Optional[jax.Array]
    scheme: str = dataclasses.field(metadata=dict(static=True))
    fan_in: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_chips(self) -> int:
        """Sampled chip instances in this ensemble (leading axis of ep/en)."""
        return self.ep.shape[0]

    @property
    def rows(self) -> int:
        """Crossbar rows per chip (bias/BN lead rows + fan-in rows)."""
        return self.ep.shape[1]

    @property
    def n_out(self) -> int:
        """Output columns per chip (bitlines after pos/neg pairing)."""
        return self.ep.shape[2]

    @property
    def lead_rows(self) -> int:
        """Always-on (bias / BN) rows prefixed ahead of the fan-in rows."""
        return self.rows - self.fan_in

    def planes_per_chip(self) -> bool:
        """True when placement planes vary per chip ([chips, rows, n_out])
        rather than being one shared [rows, n_out] copy."""
        return self.gp.ndim == 3


def chip_keys(key: jax.Array, chip_ids: jax.Array) -> jax.Array:
    """Per-chip PRNG keys: chip c <- fold_in(key, c) (the single-chip
    convention, so ensemble chip c is bit-identical to a loop iteration c)."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(chip_ids)


def sample_ensemble(key: jax.Array, mapped: MappedLayer, n_chips: int = 0,
                    *, chip_ids: Optional[jax.Array] = None,
                    cfg: ni.NonidealConfig = ni.NonidealConfig.all(),
                    spec: MacroSpec = DEFAULT_MACRO,
                    device=None) -> ChipEnsemble:
    """Sample `n_chips` chip instances of one mapped layer.

    Pass `chip_ids` instead of `n_chips` to sample an arbitrary slice of the
    logical ensemble (how the streaming engine bounds memory: chunked ids,
    one `fold_in` stream, identical chips regardless of chunking).
    `device` selects the `repro.device` backend the chip state is drawn from
    (None: analytic, bit-identical to the legacy closed forms).
    """
    if chip_ids is None:
        chip_ids = jnp.arange(n_chips, dtype=jnp.uint32)
    return sample_ensemble_with_keys(chip_keys(key, chip_ids), mapped,
                                     chip_ids=chip_ids, cfg=cfg, spec=spec,
                                     device=device)


def sample_ensemble_with_keys(keys: jax.Array, mapped: MappedLayer, *,
                              chip_ids: Optional[jax.Array] = None,
                              cfg: ni.NonidealConfig = ni.NonidealConfig.all(),
                              spec: MacroSpec = DEFAULT_MACRO,
                              device=None) -> ChipEnsemble:
    """Sample chips from EXPLICIT per-chip keys [chips] instead of the
    default `fold_in(key, c)` stream.

    This is how network-level ensembles keep each layer's key discipline:
    the detector samples (chip c, layer l, group g) with
    `fold_in(fold_in(fold_in(key, c), l), g)` so chip c of every layer
    ensemble is bit-identical to the single-chip structural path
    (`IRCDetector.apply(mode="eval", key=fold_in(key, c))`).
    """
    assert mapped.rows <= spec.rows, (
        f"planes ({mapped.rows} rows) exceed the macro ({spec.rows}); tile first")
    if chip_ids is None:
        chip_ids = jnp.arange(keys.shape[0], dtype=jnp.uint32)
    sample = jax.vmap(
        lambda k: sample_chip_planes(k, mapped.g_pos, mapped.g_neg,
                                     mapped.scheme, cfg, spec, device))
    ep, en, sa_keys = sample(keys)
    return ChipEnsemble(ep=ep, en=en, gp=mapped.g_pos, gn=mapped.g_neg,
                        sa_keys=sa_keys, chip_ids=chip_ids, bias_units=None,
                        scheme=mapped.scheme, fan_in=mapped.fan_in)


def shard_ensemble(ens: ChipEnsemble, mesh) -> ChipEnsemble:
    """Place the ensemble's chips axis over the mesh's data-parallel axes
    (the "chips" logical rule): chip state never crosses devices, so the
    vmapped forward and the chip-batched kernel run collective-free with a
    [chips/D] slice per device."""
    from jax.sharding import NamedSharding
    from repro.sharding.rules import chips_pspec

    def put(a):
        """Shard chip-leading arrays; replicate shared planes untouched."""
        if a is None or a.ndim == 0 or a.shape[0] != ens.n_chips:
            return a    # shared planes ([rows, n_out]) stay replicated
        return jax.device_put(a, NamedSharding(
            mesh, chips_pspec(mesh, ens.n_chips, a.ndim)))

    return dataclasses.replace(
        ens, ep=put(ens.ep), en=put(ens.en), gp=put(ens.gp), gn=put(ens.gn),
        sa_keys=put(ens.sa_keys), chip_ids=put(ens.chip_ids),
        bias_units=put(ens.bias_units))


def deviation_planes(ens: ChipEnsemble, spec: MacroSpec = DEFAULT_MACRO,
                     device=None) -> ChipEnsemble:
    """The ensemble with ep/en replaced by (effective - nominal) conductance
    DELTAS, for the train-time surrogate.

    The nominal planes are what a variation-free chip carries (LRS cells at
    unit conductance plus the HRS leak floor), so
    `ensemble_apply(deviation_planes(ens), x, cfg=none, output="diff")` is
    each chip's FROZEN current-difference deviation — exactly the linear
    device-variation error the structural sim would add on that die, with no
    per-read stochastic terms.  Ensemble-aware QAT adds this (stop-gradient)
    to the ideal pre-activation instead of the legacy i.i.d. noise draw.
    With `device_variation` off at sampling time the deltas are exactly zero.

    Only meaningful on an UNCALIBRATED ensemble (per-die bias masking floors
    conductances irreversibly, so deltas would mix calibration into the
    surrogate); asserts `bias_units is None`.
    """
    assert ens.bias_units is None, (
        "deviation_planes needs an uncalibrated ensemble (train-time path)")
    # the nominal planes must use the SAME leak floor the chips were sampled
    # with, so deltas are zero when variation is off under any backend
    leak = ni._device_or_analytic(device).hrs_leak_units(spec)
    gp = ens.gp if ens.planes_per_chip() else ens.gp[None]
    gn = ens.gn if ens.planes_per_chip() else ens.gn[None]
    ep0 = gp + (1.0 - gp) * leak
    en0 = gn + (1.0 - gn) * leak
    return dataclasses.replace(ens, ep=ens.ep - ep0, en=ens.en - en0)


# ------------------------------------------------------------- per-chip bias

def _chip_current_stats(x_ext: jax.Array, ep, en, gp, gn, spec: MacroSpec,
                        device=None
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(i_pos, i_neg, p_pair) of one chip on a calibration batch, with the
    physical effects the SA actually sees (variation pre-applied in ep/en,
    IR drop here) but no periphery model — mirrors
    `repro.core.calibration.layer_current_stats` on pre-sampled planes."""
    cfg = ni.NonidealConfig(device_variation=True, ir_drop=True)
    blk = spec.ir_block
    i_pos, p_pos = _accumulate(_block_reduce(x_ext, ep, blk),
                               _block_reduce(x_ext, gp, blk),
                               cfg, spec, "single_shot", 256, device)
    i_neg, p_neg = _accumulate(_block_reduce(x_ext, en, blk),
                               _block_reduce(x_ext, gn, blk),
                               cfg, spec, "single_shot", 256, device)
    return i_pos.ravel(), i_neg.ravel(), (p_pos + p_neg).ravel()


def calibrate_ensemble_bias(ens: ChipEnsemble, x_calib_bits: jax.Array,
                            spec: MacroSpec = DEFAULT_MACRO,
                            candidates: Sequence[int] = (0, 4, 8, 12, 16,
                                                         20, 24, 28, 32),
                            device=None) -> ChipEnsemble:
    """Per-die extra-bias calibration (Sec. IV-B.4 deployment flow).

    The ensemble must be sampled from a mapping whose `lead_rows` equal the
    physical bias-row budget; each chip then keeps only its calibrated count
    `b_c <= lead_rows` active.  Deactivated rows revert to HRS cells on both
    planes (conductance -> hrs_leak, LRS count -> 0), which is exactly what
    sampling the masked planes with the same key would have produced.
    """
    lead = ens.lead_rows
    assert lead > 0, "calibration needs bias rows in the mapping (lead_rows>0)"
    cand = tuple(c for c in candidates if c <= lead)
    # calibration currents are measured with the bias rows OFF (calibrate_bias
    # adds each candidate analytically)
    x_ext = jnp.concatenate(
        [jnp.zeros(x_calib_bits.shape[:-1] + (lead,), jnp.float32),
         x_calib_bits.astype(jnp.float32)], axis=-1)
    stats = jax.jit(jax.vmap(
        lambda ep, en, gp, gn: _chip_current_stats(x_ext, ep, en, gp, gn, spec,
                                                   device),
        in_axes=(0, 0, None if ens.gp.ndim == 2 else 0,
                 None if ens.gn.ndim == 2 else 0)))(
        ens.ep, ens.en, ens.gp, ens.gn)
    i_pos, i_neg, p_pair = jax.device_get(stats)
    bias = np.array([calibrate_bias(jnp.asarray(ip), jnp.asarray(ineg),
                                    jnp.asarray(pp), spec, cand)[0]
                     for ip, ineg, pp in zip(i_pos, i_neg, p_pair)],
                    np.float32)
    bias = jnp.asarray(bias)
    # row mask [chips, rows]: first b_c of the lead rows stay on
    row = jnp.arange(ens.rows, dtype=jnp.float32)
    on = ((row[None, :] < bias[:, None]) | (row[None, :] >= lead)
          ).astype(jnp.float32)
    m = on[:, :, None]
    leak = ni._device_or_analytic(device).hrs_leak_units(spec)
    gp = ens.gp if ens.gp.ndim == 3 else ens.gp[None]
    gn = ens.gn if ens.gn.ndim == 3 else ens.gn[None]
    return dataclasses.replace(
        ens,
        ep=jnp.where(m > 0, ens.ep, leak), en=jnp.where(m > 0, ens.en, leak),
        gp=gp * m, gn=gn * m, bias_units=bias)
