"""Whole-network chip-ensemble MC for the IRC detector (Table II, in the
paper's own units).

`repro.mc.engine` evaluates chip populations of ONE mapped layer and reports
bit-agreement proxies; the paper's headline result (3.85% mAP drop under all
nonideal effects vs. catastrophic baseline failure) is a statistic of the
WHOLE detector over sampled chips.  This module threads `ChipEnsemble`
through the detector stack:

  DetectorEnsemble / build_detector_ensemble
      pre-sampled per-layer, per-group chip planes.  Chip `c`, layer `l`
      (= s*10+b), group `g` is sampled with
      `fold_in(fold_in(fold_in(key, c), l), g)` — chip-consistent with
      `IRCDetector.apply`'s single-chip key discipline, so chip `c` of the
      ensemble path is bit-identical to `apply(mode="eval",
      key=fold_in(key, c))`.
  run_mc_detector / run_ablation_detector
      stream the population in chunks through the jitted ensemble structural
      path and fold each chip's HOST-side mAP@0.5 (`evaluate_map_per_chip`)
      into the engine's Welford/quantile accumulators — the same
      McConfig/McResult machinery as the layer-level sweeps.

All chips of a die design share the LRS placement planes, so each layer
ensemble stores ONE [rows, n_out] placement copy; only the effective
conductances ([chips, rows, n_out]) and SA keys are per chip.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nonideal as ni
from repro.core.macro import MacroSpec
from repro.mc.engine import McConfig, McResult, TABLE2_ABLATION
from repro.mc.ensemble import ChipEnsemble, sample_ensemble_with_keys
from repro.mc.stats import StreamingMoments
from repro.obs import ConvergenceMonitor, PhaseTimer, RunLog, as_runlog


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DetectorEnsemble:
    """A chip population of the whole detector.

    layers:   block name ("s{s}b{b}") -> per-group `ChipEnsemble`s, in the
              group order of `IRCDetector.group_mappings`.
    chip_ids: [chips] global chip indices (fold_in stream positions), shared
              by every layer ensemble — one die is one draw of EVERY layer.
    """
    layers: Dict[str, Tuple[ChipEnsemble, ...]]
    chip_ids: jax.Array

    @property
    def n_chips(self) -> int:
        """Population size: number of sampled dies in this ensemble."""
        return self.chip_ids.shape[0]


def detector_layer_keys(key: jax.Array, chip_ids: jax.Array, layer_id: int,
                        g: int) -> jax.Array:
    """Per-chip keys of one detector (layer, group) crossbar:
    `fold_in(fold_in(fold_in(key, c), layer_id), g)` — THE key stream shared
    by the eval-time ensemble builder, the train-time surrogate sampler, and
    the single-chip structural path (`IRCDetector.apply(mode="eval")` folds
    the same layer_id = s*10+b and group g)."""
    return jax.vmap(lambda i: jax.random.fold_in(
        jax.random.fold_in(jax.random.fold_in(key, i), layer_id), g))(chip_ids)


def build_detector_ensemble(key: jax.Array, det, params, n_chips: int = 0, *,
                            chip_ids: Optional[jax.Array] = None,
                            cfg: ni.NonidealConfig = ni.NonidealConfig.all(),
                            device=None) -> DetectorEnsemble:
    """Sample a chip population of every group crossbar in the detector.

    Pass `chip_ids` to sample an arbitrary slice of the logical ensemble
    (how the streaming sweep bounds memory); the key chain per (chip, layer,
    group) matches the single-chip eval path exactly.  `device` selects the
    `repro.device` backend all layer planes are drawn from (None: analytic).
    """
    dcfg = det.cfg
    if chip_ids is None:
        chip_ids = jnp.arange(n_chips, dtype=jnp.uint32)
    layers: Dict[str, Tuple[ChipEnsemble, ...]] = {}
    for s, (ch, nb) in enumerate(zip(dcfg.stage_channels,
                                     dcfg.blocks_per_stage)):
        c_in = dcfg.stage_channels[max(0, s - 1)] if s else ch
        for b in range(nb):
            cin = max(c_in if b == 0 else ch, ch)   # widen-by-repetition
            name = f"s{s}b{b}"
            groups = []
            for g, mapped in enumerate(det.group_mappings(params[name],
                                                          cin, ch)):
                keys = detector_layer_keys(key, chip_ids, s * 10 + b, g)
                groups.append(sample_ensemble_with_keys(
                    keys, mapped, chip_ids=chip_ids, cfg=cfg, spec=det.spec,
                    device=device))
            layers[name] = tuple(groups)
    return DetectorEnsemble(layers=layers, chip_ids=chip_ids)


def build_train_ensemble(key: jax.Array, det, params, n_chips: int, *,
                         chip_ids: Optional[jax.Array] = None,
                         cfg: ni.NonidealConfig = ni.NonidealConfig.all(),
                         device=None) -> DetectorEnsemble:
    """Train-time chip population: per-layer DEVIATION planes, no eval-only
    extras (per-die bias calibration, sensing periphery state).

    Same plane sampling and `detector_layer_keys` stream as the eval builder
    — chip `c` here IS chip `c` of `build_detector_ensemble` — but each
    layer's ChipEnsemble carries (effective - nominal) conductance deltas
    (`deviation_planes`), so `mode="train_ensemble"` can add each chip's
    frozen linear variation error to the differentiable QAT pre-activation.
    Everything inside is jit-traceable: the QAT step rebuilds the planes from
    the CURRENT quantized weights every step while the chip identity (the
    variation masks' keys) advances only when the caller advances `key`
    (`resample_every` scheduling lives in `repro.train.steps`).
    """
    from repro.mc.ensemble import deviation_planes
    ens = build_detector_ensemble(key, det, params, n_chips,
                                  chip_ids=chip_ids, cfg=cfg, device=device)
    return DetectorEnsemble(
        layers={name: tuple(deviation_planes(g, det.spec, device)
                            for g in groups)
                for name, groups in ens.layers.items()},
        chip_ids=ens.chip_ids)


@functools.partial(jax.jit, static_argnames=("det_cfg", "spec", "cfg_ni",
                                             "sa_extra", "use_kernel",
                                             "kernel_impl", "device"))
def _ensemble_forward(params, images, ens: DetectorEnsemble, *, det_cfg,
                      spec: MacroSpec, cfg_ni: ni.NonidealConfig,
                      sa_extra: float,
                      use_kernel: Optional[bool] = None,
                      kernel_impl: str = "pallas", device=None) -> jax.Array:
    """Module-level jitted ensemble forward: the compile cache is keyed on
    the (hashable) detector config, so repeated `run_mc_detector` calls —
    chunk streams, ablation columns, benchmark reruns — reuse one program
    per shape instead of retracing a per-call closure."""
    from repro.models.detector import IRCDetector
    det = IRCDetector(det_cfg, spec)
    return det.apply(params, images, mode="ensemble", ensemble=ens,
                     cfg_ni=cfg_ni, sa_extra=sa_extra,
                     use_kernel=use_kernel, kernel_impl=kernel_impl,
                     device=device)


def detector_planes(det, params):
    """Hoist the per-layer `group_mappings` out of the chunk loop.

    `build_detector_ensemble` re-derives every group's mapped planes from
    the current params on every call — a per-chunk host cost (quantization,
    plane assembly) that is INVARIANT across chunks of one sweep.  This
    returns the same information split for the jitted chunk program:

      planes  nested tuple pytree of (g_pos, g_neg) arrays per layer/group
              (traced jit operands — donation-safe, no Python objects);
      meta    hashable static twin: per layer (name, layer_id = s*10+b,
              per-group (bias_rows, scheme, fan_in)).
    """
    dcfg = det.cfg
    planes, meta = [], []
    for s, (ch, nb) in enumerate(zip(dcfg.stage_channels,
                                     dcfg.blocks_per_stage)):
        c_in = dcfg.stage_channels[max(0, s - 1)] if s else ch
        for b in range(nb):
            cin = max(c_in if b == 0 else ch, ch)   # widen-by-repetition
            name = f"s{s}b{b}"
            group_maps = det.group_mappings(params[name], cin, ch)
            planes.append(tuple((m.g_pos, m.g_neg) for m in group_maps))
            meta.append((name, s * 10 + b,
                         tuple((m.bias_rows, m.scheme, m.fan_in)
                               for m in group_maps)))
    return tuple(planes), tuple(meta)


def _sample_and_forward(params, images, key, chip_ids, planes, *, det_cfg,
                        spec: MacroSpec, cfg_ni: ni.NonidealConfig,
                        sa_extra: float, meta,
                        use_kernel: Optional[bool] = None,
                        kernel_impl: str = "pallas", device=None) -> jax.Array:
    """Shared trace body of `_sampled_chunk_forward` and
    `committee_wave_forward`: rebuild each group's `MappedLayer` from the
    hoisted planes/meta, sample the chunk's `DetectorEnsemble` in-trace, and
    run the ensemble structural forward.  Keeping ONE body guarantees the
    serving wave traces the exact ops of the MC chunk program per lane."""
    from repro.core.mapping import MappedLayer
    from repro.models.detector import IRCDetector
    det = IRCDetector(det_cfg, spec)
    layers: Dict[str, Tuple[ChipEnsemble, ...]] = {}
    for layer_planes, (name, layer_id, gmeta) in zip(planes, meta):
        groups = []
        for g, ((gp, gn), (bias_rows, scheme, fan_in)) in enumerate(
                zip(layer_planes, gmeta)):
            mapped = MappedLayer(g_pos=gp, g_neg=gn, bias_rows=bias_rows,
                                 scheme=scheme, fan_in=fan_in)
            keys = detector_layer_keys(key, chip_ids, layer_id, g)
            groups.append(sample_ensemble_with_keys(
                keys, mapped, chip_ids=chip_ids, cfg=cfg_ni, spec=spec,
                device=device))
        layers[name] = tuple(groups)
    ens = DetectorEnsemble(layers=layers, chip_ids=chip_ids)
    return det.apply(params, images, mode="ensemble", ensemble=ens,
                     cfg_ni=cfg_ni, sa_extra=sa_extra,
                     use_kernel=use_kernel, kernel_impl=kernel_impl,
                     device=device)


@functools.partial(jax.jit, static_argnames=("det_cfg", "spec", "cfg_ni",
                                             "sa_extra", "meta",
                                             "use_kernel", "kernel_impl",
                                             "device"))
def _sampled_chunk_forward(params, images, key, chip_ids, planes, *, det_cfg,
                           spec: MacroSpec, cfg_ni: ni.NonidealConfig,
                           sa_extra: float, meta,
                           use_kernel: Optional[bool] = None,
                           kernel_impl: str = "pallas",
                           device=None) -> jax.Array:
    """Fused chunk program for the pipelined sweep: sample the chunk's
    `DetectorEnsemble` IN-TRACE (same `detector_layer_keys` stream and
    `sample_ensemble_with_keys` ops as the eager builder — the threefry
    sampling is bitwise deterministic, so the planes, and hence the
    predictions, are bit-identical to the serial path; pinned by
    tests/test_detector_mc.py) and run the ensemble forward, all in ONE
    dispatch.  Folding the sampling into the program removes the serial
    path's per-chunk eager-dispatch overhead and lets the whole chunk run
    asynchronously while the host scores the previous one."""
    return _sample_and_forward(params, images, key, chip_ids, planes,
                               det_cfg=det_cfg, spec=spec, cfg_ni=cfg_ni,
                               sa_extra=sa_extra, meta=meta,
                               use_kernel=use_kernel, kernel_impl=kernel_impl,
                               device=device)


@functools.partial(jax.jit, static_argnames=("det_cfg", "spec", "cfg_ni",
                                             "sa_extra", "meta",
                                             "use_kernel", "kernel_impl",
                                             "device"))
def committee_wave_forward(params, images, request_keys, chip_ids, planes, *,
                           det_cfg, spec: MacroSpec,
                           cfg_ni: ni.NonidealConfig, sa_extra: float, meta,
                           use_kernel: Optional[bool] = None,
                           kernel_impl: str = "pallas",
                           device=None) -> jax.Array:
    """One serving wave: every request lane gets its OWN chip committee.

    `images` is [slots, H, W, 3] and `request_keys` is [slots] stacked PRNG
    keys (one `fold_in(root, request_id)` per lane).  Each lane is traced as
    an independent `_sample_and_forward` at batch 1 — its committee sampling
    is keyed only by that lane's request key, so a request's draws cannot
    depend on which other requests share its wave (per-read SA noise shapes
    would otherwise couple lanes through the batch axis).  The lanes are
    unrolled into ONE jitted program (`slots` is a static shape), so a wave
    still costs a single dispatch; returns [slots, chips, gh, gw, ho].

    Lane `i` is bit-identical to
    `_sampled_chunk_forward(params, images[i:i+1], request_keys[i], ...)` —
    and hence to `run_mc_detector(fold_in(root, request_id), ...)` at the
    same chip ids — pinned by tests/test_serve_detector.py.
    """
    lanes = []
    for i in range(images.shape[0]):
        out = _sample_and_forward(
            params, images[i:i + 1], request_keys[i], chip_ids, planes,
            det_cfg=det_cfg, spec=spec, cfg_ni=cfg_ni, sa_extra=sa_extra,
            meta=meta, use_kernel=use_kernel, kernel_impl=kernel_impl,
            device=device)
        lanes.append(out[:, 0])                 # [chips, gh, gw, ho]
    return jnp.stack(lanes)


def run_mc_detector(key: jax.Array, det, params, images: jax.Array,
                    gt_boxes: List[np.ndarray],
                    gt_classes: List[np.ndarray], *,
                    mc: McConfig = McConfig(),
                    sa_extra: float = 0.0,
                    obs: Optional[RunLog] = None,
                    stderr_target: Optional[float] = None,
                    pipeline: bool = True,
                    use_kernel: Optional[bool] = None,
                    kernel_impl: str = "pallas") -> McResult:
    """Stream a chip population of the WHOLE detector over an eval batch.

    Per chunk: build the chunk's `DetectorEnsemble`, run ONE jitted
    ensemble structural forward (all chips, all layers), then fold each
    chip's host-side mAP@0.5 into the streaming accumulators.  The metric
    name is "map50"; chunking is statistically invisible (chip `c` is keyed
    by `fold_in(key, c)` regardless of chunk layout).

    `pipeline=True` (default) runs the double-buffered path: the group
    mappings are hoisted out of the loop (`detector_planes`), each chunk's
    ensemble sampling is fused into its jitted forward
    (`_sampled_chunk_forward`), and chunk k+1 is DISPATCHED before chunk k's
    host-side mAP matching — the device computes the next chunk while the
    host scores the current one.  Per-chip results are bit-identical to
    `pipeline=False` (same key stream, same sampled planes, same fold
    order; pinned by tests) — early stop triggers at the same chunk
    boundary, discarding at most the one extra in-flight chunk.

    `use_kernel`/`kernel_impl` route the grouped matmuls onto the Pallas
    chip-batched kernel (see `IRCDetector._gconv_ensemble`; None defers to
    the committed autotuning table).

    `params` should carry calibrated stem-BN running stats
    (`det.calibrate_bn`) — eval-mode normalization uses them.

    `obs` streams per-chunk events (raw per-chip mAPs + running stderr) into
    a run directory; `stderr_target` stops at the first chunk boundary where
    the mAP standard error reaches the target — identical moments to the
    same-length prefix of the full run (same engine semantics as `run_mc`).
    """
    from repro.train.det_loss import evaluate_map_per_chip

    obs = as_runlog(obs)
    moments = {"map50": StreamingMoments(mc.quantiles)}
    monitor = ConvergenceMonitor(moments, stderr_target=stderr_target,
                                 runlog=obs, phase="mc_detector")
    timer = PhaseTimer("mc_detector_chunks", unit="chips")
    dev_timer = PhaseTimer("mc_detector_device", unit="chips")
    host_timer = PhaseTimer("mc_detector_host", unit="chips")
    obs.log_event("mc_start", phase="mc_detector", n_chips=mc.n_chips,
                  chunk_size=mc.chunk_size, stderr_target=stderr_target,
                  pipeline=pipeline,
                  device_model=(mc.device.name if mc.device is not None
                                else "analytic"))

    chunk_ids = [jnp.arange(lo, min(lo + mc.chunk_size, mc.n_chips),
                            dtype=jnp.uint32)
                 for lo in range(0, mc.n_chips, mc.chunk_size)]

    if pipeline:
        planes, meta = detector_planes(det, params)

        def dispatch(ids):
            """Launch one chunk's sample+forward on device, without waiting."""
            return _sampled_chunk_forward(
                params, images, key, ids, planes, det_cfg=det.cfg,
                spec=det.spec, cfg_ni=mc.cfg, sa_extra=sa_extra, meta=meta,
                use_kernel=use_kernel, kernel_impl=kernel_impl,
                device=mc.device)

        inflight = dispatch(chunk_ids[0]) if chunk_ids else None

    n_done = 0
    for chunk_i, ids in enumerate(chunk_ids):
        n_chunk = int(ids.shape[0])
        with timer.lap(items=n_chunk):
            if pipeline:
                with dev_timer.lap(items=n_chunk):
                    preds_dev = jax.block_until_ready(inflight)
                if chunk_i + 1 < len(chunk_ids):
                    # double buffer: next chunk on device DURING host scoring
                    inflight = dispatch(chunk_ids[chunk_i + 1])
            else:
                with dev_timer.lap(items=n_chunk):
                    ens = build_detector_ensemble(key, det, params,
                                                  chip_ids=ids, cfg=mc.cfg,
                                                  device=mc.device)
                    preds_dev = jax.block_until_ready(_ensemble_forward(
                        params, images, ens, det_cfg=det.cfg, spec=det.spec,
                        cfg_ni=mc.cfg, sa_extra=sa_extra,
                        use_kernel=use_kernel, kernel_impl=kernel_impl,
                        device=mc.device))
            with host_timer.lap(items=n_chunk):
                preds = np.asarray(preds_dev)
                vals = jnp.asarray(evaluate_map_per_chip(
                    preds, gt_boxes, gt_classes, det.cfg.n_anchors,
                    det.cfg.n_classes))
        n_done += n_chunk
        moments["map50"].update(vals)
        obs.log_event("chunk", phase="mc_detector", chunk=chunk_i,
                      chip_lo=int(ids[0]), chips=n_done, wall_s=timer.last_s,
                      device_s=dev_timer.last_s, host_s=host_timer.last_s,
                      values={"map50": np.asarray(jnp.ravel(vals))})
        if monitor.after_chunk(chunk_i, n_done):
            obs.log_event("early_stop", chips=n_done, requested=mc.n_chips,
                          stderr_target=stderr_target)
            break

    res = McResult(
        n_chips=n_done,
        metrics={name: m.summary() for name, m in moments.items()},
        per_chip={name: m.per_chip for name, m in moments.items()},
        wall_s=timer.total_s, chips_per_sec=timer.rate(),
        compile_s=timer.compile_s,
        device_s=dev_timer.total_s, host_s=host_timer.total_s)
    obs.log_event("mc_result", phase="mc_detector", chips=n_done,
                  requested=mc.n_chips, wall_s=res.wall_s,
                  compile_s=res.compile_s, chips_per_sec=res.chips_per_sec,
                  device_s=res.device_s, host_s=res.host_s,
                  pipeline=pipeline, metrics=res.metrics)
    return res


def run_ablation_detector(key: jax.Array, det, params, images: jax.Array,
                          gt_boxes: List[np.ndarray],
                          gt_classes: List[np.ndarray], *,
                          ablations: Sequence[Tuple[str, ni.NonidealConfig]]
                          = TABLE2_ABLATION,
                          mc: McConfig = McConfig(),
                          obs: Optional[RunLog] = None,
                          stderr_target: Optional[float] = None,
                          pipeline: bool = True,
                          use_kernel: Optional[bool] = None,
                          kernel_impl: str = "pallas"
                          ) -> Dict[str, McResult]:
    """Table II for the detector: one population mAP sweep per effect
    column, same chip key stream across columns (each effect set resamples
    the same dies' variation)."""
    obs = as_runlog(obs)
    results = {}
    for name, cfg in ablations:
        obs.log_event("ablation_column", phase="mc_detector", column=name)
        results[name] = run_mc_detector(
            key, det, params, images, gt_boxes, gt_classes,
            mc=dataclasses.replace(mc, cfg=cfg), obs=obs,
            stderr_target=stderr_target, pipeline=pipeline,
            use_kernel=use_kernel, kernel_impl=kernel_impl)
    return results
