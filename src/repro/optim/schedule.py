"""LR schedules.  `warmup_step_decay` is the paper's detector schedule:
warm up 1e-5 -> 1e-4 over the first 5 epochs, step down to 1e-5 / 1e-6 at
epochs 80 / 110 (Sec. V-A), expressed in steps."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class WarmupStepDecay:
    base_lr: float = 1e-4
    warmup_start: float = 1e-5
    warmup_steps: int = 500
    decay_points: tuple = ((8000, 1e-5), (11000, 1e-6))

    def __call__(self, step):
        return warmup_step_decay(step, self.base_lr, self.warmup_start,
                                 self.warmup_steps, self.decay_points)


def warmup_step_decay(step, base_lr=1e-4, warmup_start=1e-5,
                      warmup_steps=500, decay_points=((8000, 1e-5),
                                                      (11000, 1e-6))):
    t = jnp.asarray(step, jnp.float32)
    frac = jnp.clip(t / max(warmup_steps, 1), 0.0, 1.0)
    lr = warmup_start + frac * (base_lr - warmup_start)
    for boundary, value in decay_points:
        lr = jnp.where(t >= boundary, value, lr)
    return lr
