"""AdamW (decoupled weight decay [18]) in pure JAX.

Moments are f32 regardless of param dtype (bf16 params keep f32 optimizer
state — standard large-model practice); update math runs in f32 and casts
back to the param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 1e-3      # paper's detector training setting
    grad_clip: float = 1.0


def adamw_init(params: PyTree) -> Dict[str, PyTree]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(grads: PyTree, state: Dict[str, PyTree], params: PyTree,
                 lr: jax.Array, cfg: AdamWConfig = AdamWConfig()
                 ) -> Tuple[PyTree, Dict[str, PyTree], Dict[str, jax.Array]]:
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m_new, v_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm}
