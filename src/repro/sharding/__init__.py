from repro.sharding.rules import (LOGICAL_RULES, spec_for_axes,
                                  tree_pspecs, tree_shardings,
                                  batch_pspec, chips_pspec, cache_axes_tree)
