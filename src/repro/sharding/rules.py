"""Logical-axis -> mesh-axis sharding rules (MaxText-style), with
divisibility fixup so every (arch x shape x mesh) cell gets a VALID
PartitionSpec: an axis that does not divide its dimension is dropped
(replicated) rather than crashing the lowering.

Parallelism encoded here:
  * FSDP/ZeRO-3: parameter + optimizer sharding over ("pod","data") via the
    "embed"/"vocab-embed" rules — XLA inserts per-layer all-gathers inside
    the layer scan (overlapping with compute).
  * TP (Megatron col->row): "heads_qkv"/"kv_qkv"/"mlp" over "model".
  * EP: "experts" over "model" (expert FFNs live with their experts; the
    dispatch scatter induces the all-to-all).
  * DP: activation batch over ("pod","data").
  * SP: long-context decode KV caches shard the SEQUENCE dim over "model"
    (flash-decoding style), since batch=1 cannot absorb the mesh.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# logical axis -> preferred mesh axes (tried in order, dropped if they
# don't divide or are already taken by an earlier dim of the same tensor)
LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "layers": (),                       # scanned; never sharded
    "vocab": ("model",),
    "embed": ("pod", "data"),          # FSDP axis for params
    "heads_qkv": ("model",),
    "kv_qkv": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    # Monte Carlo chip ensembles (repro.mc): the chips axis is embarrassingly
    # parallel — shard sampled-chip state and per-chip activations over every
    # data-parallel axis, replicate the shared input batch
    "chips": ("pod", "data"),
    # activations / caches
    "act_batch": ("pod", "data"),
    "act_seq": (),
    "act_seq_model": ("model",),        # SP for decode caches
    "act_heads": ("model",),
    "act_embed": (),
}


def _mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for_axes(axes: Sequence[Optional[str]], shape: Sequence[int],
                  mesh: Mesh,
                  overrides: Optional[Dict[str, Tuple[str, ...]]] = None
                  ) -> P:
    """Resolve logical axes to a valid PartitionSpec for `shape` on `mesh`.

    Drops mesh axes that (a) don't exist on this mesh, (b) don't divide the
    dimension, or (c) were already used by an earlier dimension.
    """
    rules = dict(LOGICAL_RULES)
    if overrides:
        rules.update(overrides)
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    parts = []
    for dim, ax in zip(shape, axes):
        if ax is None or ax not in rules:
            parts.append(None)
            continue
        chosen = []
        prod = 1
        for m in rules[ax]:
            if m not in sizes or m in used:
                continue
            if dim % (prod * sizes[m]) == 0:
                chosen.append(m)
                prod *= sizes[m]
        for m in chosen:
            used.add(m)
        if not chosen:
            parts.append(None)
        elif len(chosen) == 1:
            parts.append(chosen[0])
        else:
            parts.append(tuple(chosen))
    return P(*parts)


def tree_pspecs(axes_tree: PyTree, shape_tree: PyTree, mesh: Mesh,
                overrides=None) -> PyTree:
    """Map (logical axes tree, abstract shapes tree) -> PartitionSpec tree."""
    return jax.tree.map(
        lambda axes, sds: spec_for_axes(axes, sds.shape, mesh, overrides),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))


def tree_shardings(axes_tree: PyTree, shape_tree: PyTree, mesh: Mesh,
                   overrides=None) -> PyTree:
    return jax.tree.map(lambda p: NamedSharding(mesh, p),
                        tree_pspecs(axes_tree, shape_tree, mesh, overrides),
                        is_leaf=lambda x: isinstance(x, P))


def batch_pspec(mesh: Mesh) -> P:
    """[B, S] token batches: batch over every data-parallel axis present."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None), None)


def chips_pspec(mesh: Mesh, n_chips: int, ndim: int) -> P:
    """Leading-chips-axis spec for ensemble state / activations, via the
    "chips" logical rule (divisibility fixup included: an awkward chunk size
    falls back to replication rather than crashing the device_put)."""
    return spec_for_axes(("chips",) + (None,) * (ndim - 1),
                         (n_chips,) + (1,) * (ndim - 1), mesh)


# ------------------------------------------------------------------ caches

def cache_axes_tree(cache_abstract: PyTree) -> PyTree:
    """Logical axes for a decode cache built by LM.init_cache.

    KV caches [L,B,S,KV,hd]: batch over DP axes, sequence over 'model'
    (SP / flash-decoding split — batch=1 long-context cells can't absorb
    the mesh on batch alone; KV head counts rarely divide it).
    SSM/RWKV states: batch over DP axes, feature dim over 'model'.
    """
    def leaf_axes(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        nd = leaf.ndim
        if "index" in names:
            return (None,) * nd
        if nd == 5 and names[-1] in ("k", "v"):        # [L,B,S,KV,hd]
            return ("layers", "act_batch", "act_seq_model", None, None)
        if names[-1] == "wkv":                          # [L,B,H,hd,hd]
            return ("layers", "act_batch", "act_heads", None, None)
        if names[-1] == "h":                            # [L,B,di,n]
            return ("layers", "act_batch", "heads_qkv", None)
        if names[-1] == "conv":                         # [L,B,K-1,di]
            return ("layers", "act_batch", None, "heads_qkv")
        if nd == 3:                                     # shift states [L,B,D]
            return ("layers", "act_batch", None)
        return (None,) * nd

    return jax.tree_util.tree_map_with_path(leaf_axes, cache_abstract)
