"""`DeviceModel` — the pluggable seam between the RRAM device physics and
everything that consumes it (crossbar sim, MC engine, detector, serving).

The paper's robustness story rests on analytic models of device variation,
SA offset and IR drop; this interface makes those planes come from
*interchangeable* sources — the closed-form models (`AnalyticDeviceModel`),
measured variation / I-V datasets (`MeasuredDeviceModel`), or any backend
wrapped in an aging timeline (`RetentionDrift`) — without touching the MC
engine or the detector.  Every consumer takes `device=None` and resolves it
through `default_device`, so the legacy call sites stay bit-identical to the
pre-seam code (the analytic implementation IS the old math, moved).

Contract for implementations (see docs/device-models.md):

  * every hook is a pure function of its inputs — no hidden state, no host
    RNG; stochastic draws consume ONLY the passed key (the fold_in key
    discipline of `repro.mc` depends on it);
  * instances must be hashable and cheaply equal-comparable (frozen
    dataclasses with tuple/float fields) — they ride through `jax.jit` as
    static arguments, so an unhashable model would fail to trace and a
    hash-unstable one would retrigger compilation;
  * hooks returning Python floats (`hrs_leak_units`) must not trace: they
    feed Python-level control flow at trace time.
"""
from __future__ import annotations

import abc

import jax

from repro.core import nonideal as ni
from repro.core.macro import MacroSpec, DEFAULT_MACRO


class DeviceModel(abc.ABC):
    """Where conductance planes and periphery statistics come from.

    Device-side hooks (`variation_mask`, `hrs_leak_units`) are abstract —
    they are what distinguishes an analytic fit from a measured array.
    Periphery-side hooks (`sa_offset_sigma`, `ir_drop_factors`) default to
    the paper's circuit models, shared by all device backends; a backend
    that overrides them must also clear `analytic_periphery` so the fused
    Pallas kernel path (whose epilogue hardcodes the analytic periphery)
    refuses to route it instead of silently computing the wrong thing.
    """

    #: short backend identifier, recorded in run manifests and bench rows
    name: str = "base"

    @property
    def analytic_periphery(self) -> bool:
        """True while SA-offset/IR-drop hooks are the analytic closed forms
        (the contract the fused kernel epilogue bakes in)."""
        return True

    @abc.abstractmethod
    def variation_mask(self, key: jax.Array, shape,
                       spec: MacroSpec = DEFAULT_MACRO) -> jax.Array:
        """Per-cell multiplicative current mask for programmed LRS cells.

        Drawn once per chip at programming time (`sample_chip_planes`), not
        per read.  Must consume only `key`; shape/dtype: `shape` float32.
        """

    @abc.abstractmethod
    def hrs_leak_units(self, spec: MacroSpec = DEFAULT_MACRO) -> float:
        """HRS (non-formed cell) leak current in LRS units, as a PYTHON
        float — it parameterizes the conductance mapping at trace time
        (`ep = ep + (1 - g_pos) * leak`) and gates Python control flow."""

    def sa_offset_sigma(self, p: jax.Array, spec: MacroSpec = DEFAULT_MACRO,
                        extra_units: float = 0.0) -> jax.Array:
        """Std of the input-referred SA offset current at activated-LRS
        count `p` — analytic default: half the required difference g(p)
        from the paper's Fig. 9 (+ the Table IV tolerance margin)."""
        return 0.5 * (ni.sa_required_diff(p, spec) + extra_units)

    def ir_drop_factors(self, block_currents: jax.Array,
                        spec: MacroSpec = DEFAULT_MACRO,
                        axis: int = -1) -> jax.Array:
        """Per-block current-retention factors along a bit-line — analytic
        default: the paper's linear cumulative-wire-drop model."""
        return ni.ir_drop_factors(block_currents, spec.ir_alpha, axis=axis)
