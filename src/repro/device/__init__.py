"""repro.device — pluggable device-model backends (the physics seam).

Public surface:
  DeviceModel            the interface (docs/device-models.md)
  AnalyticDeviceModel    the paper's closed forms (bit-identical default)
  MeasuredDeviceModel    tabulated variation/I-V datasets
  RetentionDrift         time-parameterized aging wrapper (t_days)
  get_device_model       name -> model (CLI / manifest registry)
  default_device         resolve `device=None` to the analytic singleton
"""
from repro.device.base import DeviceModel
from repro.device.analytic import (AnalyticDeviceModel, ANALYTIC_DEVICE,
                                   default_device)
from repro.device.measured import MeasuredDeviceModel, SAMPLE_DATASET
from repro.device.retention import RetentionDrift
from repro.device.registry import get_device_model, DEVICE_MODELS
