"""Measured device backend: tabulated variation quantiles + I-V curves.

A real array's LRS spread rarely matches the closed-form fit exactly; this
backend draws variation through the INVERSE-CDF of a measured quantile
table (z ~ N(0,1) -> u = Phi(z) -> linear interpolation of the tabulated
current factor at quantile u), so any digitized distribution plugs in
without re-deriving a parametric fit.  The HRS leak comes from the measured
LRS/HRS I-V table at the spec's read voltage instead of the spec constant.

Tables are stored as tuples (hashable — the model rides through `jax.jit`
as a static argument) and ship as JSON under `repro/device/data/`; see
docs/device-models.md for the dataset format and how to register your own.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.macro import MacroSpec, DEFAULT_MACRO
from repro.device.base import DeviceModel

#: packaged sample datasets live next to this module
DATA_DIR = Path(__file__).resolve().parent / "data"

#: the default packaged dataset (paper-scale 40nm RRAM sample table)
SAMPLE_DATASET = DATA_DIR / "sample_lrs_40nm.json"


@dataclasses.dataclass(frozen=True)
class MeasuredDeviceModel(DeviceModel):
    """Interpolating backend over a measured variation/I-V dataset.

    dataset:     dataset name (from the JSON), recorded in manifests.
    var_q:       variation quantile grid, strictly increasing in (0, 1).
    var_factor:  LRS current factor at each quantile (median ~ 1.0).
    iv_v:        I-V voltage grid (V across the 1T1R cell).
    iv_lrs_ua:   measured LRS cell current (uA) at each voltage.
    iv_hrs_ua:   measured HRS cell current (uA) at each voltage.
    """

    dataset: str
    var_q: Tuple[float, ...]
    var_factor: Tuple[float, ...]
    iv_v: Tuple[float, ...]
    iv_lrs_ua: Tuple[float, ...]
    iv_hrs_ua: Tuple[float, ...]

    name = "measured"

    @classmethod
    def from_file(cls, path: Optional[Union[str, Path]] = None
                  ) -> "MeasuredDeviceModel":
        """Load a dataset JSON (default: the packaged sample table).

        Expected schema — see docs/device-models.md:
          {"name": ..., "variation": {"quantile": [...], "factor": [...]},
           "iv": {"v": [...], "i_lrs_ua": [...], "i_hrs_ua": [...]}}
        """
        p = Path(path) if path is not None else SAMPLE_DATASET
        d = json.loads(p.read_text())
        q = tuple(float(v) for v in d["variation"]["quantile"])
        f = tuple(float(v) for v in d["variation"]["factor"])
        if len(q) != len(f) or len(q) < 2:
            raise ValueError(f"{p}: variation table needs >= 2 aligned "
                             f"(quantile, factor) points")
        if any(b <= a for a, b in zip(q, q[1:])):
            raise ValueError(f"{p}: variation quantiles must be strictly "
                             f"increasing")
        iv = d["iv"]
        return cls(dataset=str(d.get("name", p.stem)), var_q=q, var_factor=f,
                   iv_v=tuple(float(v) for v in iv["v"]),
                   iv_lrs_ua=tuple(float(v) for v in iv["i_lrs_ua"]),
                   iv_hrs_ua=tuple(float(v) for v in iv["i_hrs_ua"]))

    def variation_factor(self, u: jax.Array) -> jax.Array:
        """Tabulated inverse CDF: quantile u in [0, 1] -> LRS current
        factor.  Linear between grid points; beyond the measured extremes
        the factor clamps to the end values (jnp.interp semantics) — the
        tails a finite measurement cannot speak to."""
        return jnp.interp(u, jnp.asarray(self.var_q, jnp.float32),
                          jnp.asarray(self.var_factor, jnp.float32))

    def variation_mask(self, key: jax.Array, shape,
                       spec: MacroSpec = DEFAULT_MACRO) -> jax.Array:
        """Per-cell mask via inverse-CDF sampling of the measured table.

        Consumes `key` exactly like the analytic backend (one standard
        normal per cell), so swapping backends never shifts any OTHER draw
        in the fold_in stream.  `spec.sigma_lrs` is ignored — the spread is
        the dataset's.
        """
        z = jax.random.normal(key, shape, dtype=jnp.float32)
        u = jax.scipy.stats.norm.cdf(z)
        return self.variation_factor(u).astype(jnp.float32)

    def hrs_leak_units(self, spec: MacroSpec = DEFAULT_MACRO) -> float:
        """HRS/LRS current ratio from the measured I-V table at the spec's
        read voltage (host-side numpy interpolation — a Python float)."""
        lrs = float(np.interp(spec.v_read, self.iv_v, self.iv_lrs_ua))
        hrs = float(np.interp(spec.v_read, self.iv_v, self.iv_hrs_ua))
        return hrs / lrs
