"""Retention/drift timelines: time-parameterize ANY device backend.

ReRAM conductance is not stable over deployment time: programmed LRS cells
drift toward higher resistance (power-law decay, the standard
G(t) = G0 * (1 + t/t0)^-nu retention model) and the cell-to-cell spread
widens as individual cells drift at different rates.  `RetentionDrift`
wraps any `DeviceModel` with both effects at age `t_days`, so
`run_mc_detector` / `run_ablation_detector` sweeps over a list of ages
produce "mAP after N days" curves from the same chip key stream
(`launch.mc --t-days 0,30,365`).

At `t_days=0` the wrapper is EXACTLY the identity — it returns the base
backend's arrays untouched and consumes no extra randomness — so a zero-age
sweep is bit-identical to the unwrapped backend (pinned by
tests/test_device.py).  The per-cell drift draw is keyed by
`fold_in(key, _DRIFT_SALT)`, leaving the base backend's consumption of
`key` unchanged: chip c's day-0 identity is preserved inside its own aging
curve.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.macro import MacroSpec, DEFAULT_MACRO
from repro.device.base import DeviceModel

#: key-domain salt separating the drift draw from the base variation draw
#: (outside the small chip/layer/group fold_in lattices, so it cannot
#: collide with any chip-identity stream)
_DRIFT_SALT = 0x0D21F7


@dataclasses.dataclass(frozen=True)
class RetentionDrift(DeviceModel):
    """Age a device backend by `t_days`.

    base:        the wrapped backend (analytic, measured, ...).
    t_days:      deployment age in days (0 = programming day, identity).
    t0_days:     retention time constant of the power-law decay.
    drift_nu:    decay exponent — median LRS current falls as
                 (1 + t/t0)^-nu (~3% at 30 days, ~7% at a year, defaults).
    spread_rate: log-space sigma growth per log-time unit — per-cell drift
                 dispersion, sigma_d(t) = spread_rate * log1p(t/t0).

    HRS cells are non-formed and effectively stable (>1e9 ohm), so the leak
    and the periphery hooks delegate to the base backend unchanged; aging
    acts through the LRS variation planes only.
    """

    base: DeviceModel
    t_days: float = 0.0
    t0_days: float = 1.0
    drift_nu: float = 0.05
    spread_rate: float = 0.02

    @property
    def name(self) -> str:
        """Backend id with the age stamped in (for manifests/bench rows)."""
        return f"{self.base.name}@t{self.t_days:g}d"

    @property
    def analytic_periphery(self) -> bool:
        """Aging touches the device planes only — periphery is the base's."""
        return self.base.analytic_periphery

    def _decay(self) -> float:
        """Median current-retention factor at age t (Python float)."""
        return float((1.0 + self.t_days / self.t0_days) ** (-self.drift_nu))

    def _spread_sigma(self) -> float:
        """Log-space sigma of the per-cell drift dispersion at age t."""
        return float(self.spread_rate * math.log1p(self.t_days / self.t0_days))

    def variation_mask(self, key: jax.Array, shape,
                       spec: MacroSpec = DEFAULT_MACRO) -> jax.Array:
        """Base variation mask times the age-t drift factor.

        The drift draw consumes `fold_in(key, _DRIFT_SALT)` — the base
        backend sees `key` itself, so day-0 and day-N share the programming
        draw and differ only by the aging term.  At t_days=0 the base mask
        is returned UNTOUCHED (no extra ops, no extra key use).
        """
        mask = self.base.variation_mask(key, shape, spec)
        if self.t_days == 0.0:
            return mask
        z = jax.random.normal(jax.random.fold_in(key, _DRIFT_SALT), shape,
                              dtype=jnp.float32)
        drift = self._decay() * jnp.exp(self._spread_sigma() * z)
        return mask * drift

    def hrs_leak_units(self, spec: MacroSpec = DEFAULT_MACRO) -> float:
        """HRS cells are stable: the base backend's leak."""
        return self.base.hrs_leak_units(spec)

    def sa_offset_sigma(self, p: jax.Array, spec: MacroSpec = DEFAULT_MACRO,
                        extra_units: float = 0.0) -> jax.Array:
        """Periphery does not age in this model: delegate to the base."""
        return self.base.sa_offset_sigma(p, spec, extra_units)

    def ir_drop_factors(self, block_currents: jax.Array,
                        spec: MacroSpec = DEFAULT_MACRO,
                        axis: int = -1) -> jax.Array:
        """Wire parasitics do not age in this model: delegate to the base."""
        return self.base.ir_drop_factors(block_currents, spec, axis=axis)
