"""Named device-model construction for CLIs, benches and CI smokes.

`get_device_model("measured", t_days=30)` is the one-liner behind
`launch.mc --device-model measured --t-days 30`: resolve the backend name,
optionally wrap it in a `RetentionDrift` timeline.  Library code should
take `device=` objects directly; this registry exists so flags, manifests
and bench rows can speak in stable short names.
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional, Union

from repro.device.analytic import ANALYTIC_DEVICE
from repro.device.base import DeviceModel
from repro.device.measured import MeasuredDeviceModel
from repro.device.retention import RetentionDrift

#: backend names accepted by `get_device_model` / `launch.mc --device-model`
DEVICE_MODELS = ("analytic", "measured")


def get_device_model(name: str = "analytic", t_days: float = 0.0, *,
                     data: Optional[Union[str, Path]] = None) -> DeviceModel:
    """Build a device model by name, aged by `t_days`.

    name:   "analytic" (the paper's closed forms) or "measured" (the
            packaged sample dataset, or `data=` for your own JSON).
    t_days: deployment age; non-zero wraps the backend in `RetentionDrift`
            (0 returns the bare backend — bit-identical to the legacy path
            for "analytic").
    """
    if name == "analytic":
        base: DeviceModel = ANALYTIC_DEVICE
    elif name == "measured":
        base = MeasuredDeviceModel.from_file(data)
    else:
        raise ValueError(f"unknown device model {name!r} "
                         f"(choices: {', '.join(DEVICE_MODELS)})")
    if t_days:
        return RetentionDrift(base=base, t_days=float(t_days))
    return base
