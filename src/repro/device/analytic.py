"""The analytic device backend: the paper's closed-form models, verbatim.

`AnalyticDeviceModel` is the normative implementation of the `DeviceModel`
seam — its hooks are EXACTLY the expressions the pre-seam code inlined
(`ni.sample_variation_mask` with `spec.sigma_lrs`, `spec.hrs_leak`, the
Fig. 9 SA polynomial, the linear IR-drop model), in the same op order, so
`device=None` / `device=AnalyticDeviceModel()` is bit-identical to the
historical sampling path (pinned by tests/test_device.py).
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core import nonideal as ni
from repro.core.macro import MacroSpec, DEFAULT_MACRO
from repro.device.base import DeviceModel


@dataclasses.dataclass(frozen=True)
class AnalyticDeviceModel(DeviceModel):
    """Closed-form log-normal variation + spec-driven HRS leak (the paper's
    measured fits, parameterized entirely by `MacroSpec`)."""

    name = "analytic"

    def variation_mask(self, key: jax.Array, shape,
                       spec: MacroSpec = DEFAULT_MACRO) -> jax.Array:
        """Log-normal per-cell mask at the spec's operating-point sigma —
        the exact draw `sample_chip_planes` historically made."""
        return ni.sample_variation_mask(key, shape, spec.sigma_lrs)

    def hrs_leak_units(self, spec: MacroSpec = DEFAULT_MACRO) -> float:
        """The spec's HRS leak constant (~1e-4 units: 1e9 vs 1e5 ohm)."""
        return float(spec.hrs_leak)


#: the process-wide analytic singleton every `device=None` seam resolves to
ANALYTIC_DEVICE = AnalyticDeviceModel()


def default_device(device):
    """Resolve a `device=` argument: None means the analytic backend."""
    return ANALYTIC_DEVICE if device is None else device
