"""repro.core — the paper's contribution: hardware-robust in-RRAM computing.

Public surface:
  MacroSpec / DEFAULT_MACRO      physical macro description + power model
  NonidealConfig                 Table-II effect toggles
  crossbar_forward               full structural crossbar simulation
  IRCLinear / IRCLinearConfig    trainable IRC layer (QAT + structural eval)
  ternary_quantize / binary_quantize / binary_activation   STE quantizers
  ternary_planes / binary_planes crossbar mapping schemes
  calibrate_bias                 layerwise extra-bias calibration (Table I)
"""
from repro.core.macro import MacroSpec, DEFAULT_MACRO, wl_point, WL_OPERATING_POINTS
from repro.core.nonideal import (NonidealConfig, sample_variation_mask,
                                 nonlinearity_ratio, apply_nonlinearity,
                                 ir_drop_factors, apply_ir_drop,
                                 sa_required_diff, sa_offset, sensing_failure,
                                 resolve_sa)
from repro.core.ternary import (ternary_quantize, binary_quantize,
                                binary_activation, soft_sa_output,
                                ternary_fractions, distribution_regularizer)
from repro.core.mapping import (MappedLayer, ternary_planes, binary_planes,
                                extend_inputs, tile_rows, fold_bn_to_bias_units)
from repro.core.crossbar import (crossbar_forward, crossbar_apply,
                                 sample_chip_planes, irc_linear_train,
                                 IRCLinear, IRCLinearConfig,
                                 ideal_ternary_matmul, variation_noise_std)
from repro.core.calibration import calibrate_bias, sa_error_rates, layer_current_stats
