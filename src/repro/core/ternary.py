"""Binary/ternary quantizers with straight-through estimators (paper Sec. IV-B).

The proposed design uses ternary weights (0, +/-1) with the distribution
regulated to 20/60/20 (-1/0/+1) per filter group, and binary {0,1}
activations (a word-line is either driven or not).  The baseline design uses
binary +/-1 weights.  All quantizers are differentiable via STE so the same
functions serve QAT ("retraining" in the paper) and inference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _ste(hard: jax.Array, soft: jax.Array) -> jax.Array:
    """hard value forward, soft gradient backward."""
    return soft + jax.lax.stop_gradient(hard - soft)


# ------------------------------------------------------------------ weights

def _sorted_threshold(w: jax.Array, frac: float, axis) -> jax.Array:
    """frac-quantile via sort + static index (jnp.quantile's gather lowering
    is broken under trace in this jaxlib build). Thresholds carry no
    gradient (they are distribution statistics, constants under STE) —
    stop_gradient BEFORE the sort also keeps this jaxlib's broken sort-JVP
    gather lowering out of the trace."""
    w = jax.lax.stop_gradient(w)
    if axis is None:
        ws = jnp.sort(w.ravel())
        k = min(int(frac * (ws.shape[0] - 1) + 0.5), ws.shape[0] - 1)
        t = ws[k]
    else:
        axes = (axis,) if isinstance(axis, int) else tuple(axis)
        axes = tuple(a % w.ndim for a in axes)
        keep = [a for a in range(w.ndim) if a not in axes]
        perm = keep + list(axes)
        wt = jnp.transpose(w, perm)
        lead = wt.shape[:len(keep)]
        ws = jnp.sort(wt.reshape(lead + (-1,)), axis=-1)
        k = min(int(frac * (ws.shape[-1] - 1) + 0.5), ws.shape[-1] - 1)
        t = ws[..., k]
        # restore keepdims shape aligned with w
        shape = [1] * w.ndim
        for i, a in enumerate(keep):
            shape[a] = w.shape[a]
        t = t.reshape(shape)
    return jax.lax.stop_gradient(t)


def ternary_quantize(w: jax.Array, lo_frac: float = 0.2, hi_frac: float = 0.2,
                     axis=None) -> jax.Array:
    """Quantile-regulated ternary quantization to {-1, 0, +1}.

    Thresholds are the per-group `lo_frac` / `1-hi_frac` quantiles of the
    latent weights, so the quantized distribution is exactly
    (lo_frac, 1-lo_frac-hi_frac, hi_frac) — the paper's 20/60/20 "weight
    distribution regulation" made deterministic.  `axis=None` regulates over
    the whole tensor; pass a tuple of axes to regulate per filter group
    (e.g. per expert or per output-channel group).
    """
    t_lo = _sorted_threshold(w, lo_frac, axis)
    t_hi = _sorted_threshold(w, 1.0 - hi_frac, axis)
    hard = jnp.where(w <= t_lo, -1.0, jnp.where(w >= t_hi, 1.0, 0.0))
    return _ste(hard.astype(w.dtype), jnp.clip(w, -1.0, 1.0))


def binary_quantize(w: jax.Array) -> jax.Array:
    """Sign binarization to {-1, +1} with clipped-identity STE (baseline)."""
    hard = jnp.where(w >= 0, 1.0, -1.0)
    return _ste(hard.astype(w.dtype), jnp.clip(w, -1.0, 1.0))


def ternary_fractions(w_t: jax.Array) -> jax.Array:
    """Fractions of (-1, 0, +1) — used by tests and the power model
    (cell distribution: 20% LRS / 80% HRS with 20/60/20 regulation)."""
    n = w_t.size
    neg = jnp.sum(w_t < -0.5) / n
    pos = jnp.sum(w_t > 0.5) / n
    return jnp.stack([neg, 1.0 - neg - pos, pos])


def distribution_regularizer(w: jax.Array, lo_frac: float = 0.2,
                             hi_frac: float = 0.2) -> jax.Array:
    """Soft penalty pulling the latent weight distribution toward the
    regulated shape (keeps the quantile thresholds well-separated).  The
    quantile quantizer already enforces the hard fractions; this term keeps
    latent weights from collapsing to a point where the quantiles are
    degenerate."""
    med = jnp.mean(w)
    spread = jnp.mean(jnp.abs(w - med))
    return jnp.square(1.0 - spread) * (lo_frac + hi_frac)


# ------------------------------------------------------------------ activations

def binary_activation(x: jax.Array) -> jax.Array:
    """Step activation to {0, 1} (word-line on/off) with hard-tanh-window STE."""
    hard = (x > 0).astype(x.dtype)
    soft = jnp.clip(0.5 * (x + 1.0), 0.0, 1.0)   # gradient window |x| <= 1
    return _ste(hard, soft)


def soft_sa_output(diff: jax.Array, beta: float = 4.0) -> jax.Array:
    """Differentiable surrogate of the binary SA for variation-aware training:
    sigmoid(beta * diff) forward-approximates the comparator; used with
    reparametrized nonideal noise during QAT, hard comparison at inference."""
    hard = (diff > 0).astype(diff.dtype)
    soft = jax.nn.sigmoid(beta * diff)
    return _ste(hard, soft)
