"""Crossbar forward simulation + IRC layer modules (the paper's core).

Two execution paths, mirroring the paper's methodology:

  * `crossbar_forward` — the full structural simulation used at INFERENCE /
    evaluation time: conductance planes, per-cell device variation, 32-cell
    IR-drop blocks, accumulation nonlinearity (single-shot vs partial-sum),
    SA offset + limited sensing range.  This is the function the Pallas
    kernel (`repro.kernels.irc_mvm`) accelerates.
  * `irc_linear_train` — the differentiable surrogate used for QAT /
    "retraining": ideal ternary matmul + reparametrized noise matching the
    first-order statistics of the structural sim, with STE quantizers.

Accumulation modes (Sec. III-C / IV-B.3):
  * "single_shot": the whole column accumulates analog in one operation
    (proposed; enabled by the lowered word-line voltage).  The monotone
    nonlinearity then cancels in the differential comparison.
  * "partial_sum": the column is split into `partial_rows`-row chunks whose
    currents are accumulated externally (baseline; forced by the 300 uA
    bit-line limit at nominal word-line voltage).  Each chunk sees its own
    nonlinearity, which does NOT cancel.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.macro import MacroSpec, DEFAULT_MACRO
from repro.core import nonideal as ni
from repro.core.mapping import MappedLayer, extend_inputs
from repro.core.ternary import (ternary_quantize, binary_quantize,
                                binary_activation, soft_sa_output)


# ------------------------------------------------------------------ structural sim

def _block_reduce(x_ext: jax.Array, plane: jax.Array, block: int
                  ) -> jax.Array:
    """Per-IR-block partial currents: x_ext [..., R], plane [R, N]
    -> [..., nb, N] with nb = ceil(R / block)."""
    rows, n_out = plane.shape
    nb = -(-rows // block)
    pad = nb * block - rows
    if pad:
        x_ext = jnp.pad(x_ext, [(0, 0)] * (x_ext.ndim - 1) + [(0, pad)])
        plane = jnp.pad(plane, ((0, pad), (0, 0)))
    xb = x_ext.reshape(x_ext.shape[:-1] + (nb, block))
    pb = plane.reshape(nb, block, n_out)
    return jnp.einsum("...bk,bkn->...bn", xb, pb)


def _accumulate(blocks: jax.Array, counts: jax.Array, cfg: ni.NonidealConfig,
                spec: MacroSpec, accumulation: str, partial_rows: int,
                device=None) -> Tuple[jax.Array, jax.Array]:
    """Apply IR drop + nonlinearity to per-block currents.

    blocks/counts: [..., nb, N] (currents with variation / ideal LRS counts).
    Returns (bit-line current [..., N], activated LRS count [..., N]).
    `device` routes the IR-drop factors through a `repro.device` backend
    (None: the analytic linear wire model, bit-identical).
    """
    if cfg.ir_drop:
        blocks = blocks * ni._device_or_analytic(device).ir_drop_factors(
            blocks, spec, axis=-2)
    p_total = jnp.sum(counts, axis=-2)
    if accumulation == "single_shot":
        i_line = jnp.sum(blocks, axis=-2)
        if cfg.nonlinearity:
            i_line = ni.apply_nonlinearity(i_line, p_total)
    elif accumulation == "partial_sum":
        nb = blocks.shape[-2]
        chunk = max(1, partial_rows // spec.ir_block)
        n_chunks = -(-nb // chunk)
        pad = n_chunks * chunk - nb
        if pad:
            zeros = [(0, 0)] * blocks.ndim
            zeros[-2] = (0, pad)
            blocks = jnp.pad(blocks, zeros)
            counts = jnp.pad(counts, zeros)
        cshape = blocks.shape[:-2] + (n_chunks, chunk, blocks.shape[-1])
        i_chunk = jnp.sum(blocks.reshape(cshape), axis=-2)
        p_chunk = jnp.sum(counts.reshape(cshape), axis=-2)
        if cfg.nonlinearity:
            i_chunk = ni.apply_nonlinearity(i_chunk, p_chunk)
        i_line = jnp.sum(i_chunk, axis=-2)
    else:
        raise ValueError(f"unknown accumulation mode: {accumulation}")
    return i_line, p_total


def sample_chip_planes(key: jax.Array, g_pos: jax.Array, g_neg: jax.Array,
                       scheme: str, cfg: ni.NonidealConfig,
                       spec: MacroSpec = DEFAULT_MACRO, device=None
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sample ONE chip instance: effective conductance planes + SA key.

    Programming a die is static — the device-variation masks are drawn once
    per chip, not per MVM.  Returns (ep, en, k_sa) where ep/en carry the
    per-cell variation and HRS leak, and k_sa seeds the (per-read) peripheral
    stochastic terms.  Key-split discipline matches the historical
    `crossbar_forward` exactly, so `crossbar_forward(key, ...)` ==
    `crossbar_apply(k_sa, ..., *sample_chip_planes(key, ...)[:2])`.

    `device` selects the `repro.device` backend the variation masks and HRS
    leak come from (None: analytic — the historical closed forms,
    bit-identical; pinned by tests/test_device.py).  Each mask consumes the
    same split key regardless of backend, so swapping backends never shifts
    any other draw in the key stream.
    """
    dev = ni._device_or_analytic(device)
    k_var_p, k_var_n, k_sa = jax.random.split(key, 3)
    ep, en = g_pos, g_neg
    if cfg.device_variation:
        ep = g_pos * dev.variation_mask(k_var_p, g_pos.shape, spec)
        if scheme == "binary":
            # ONE shared physical reference line: its per-cell variation is
            # common to every output channel (input-dependent common offset,
            # Sec. IV-B.1)
            en = g_neg * dev.variation_mask(k_var_n, (g_neg.shape[0], 1),
                                            spec)
        else:
            en = g_neg * dev.variation_mask(k_var_n, g_neg.shape, spec)
    leak = dev.hrs_leak_units(spec)
    if leak:
        ep = ep + (1.0 - g_pos) * leak
        en = en + (1.0 - g_neg) * leak
    return ep, en, k_sa


def crossbar_apply(k_sa: jax.Array, x_ext: jax.Array,
                   ep: jax.Array, en: jax.Array,
                   gp: jax.Array, gn: jax.Array, *,
                   cfg: ni.NonidealConfig = ni.NonidealConfig.none(),
                   spec: MacroSpec = DEFAULT_MACRO,
                   accumulation: str = "single_shot",
                   partial_rows: int = 256,
                   sa_extra_units: float = 0.0,
                   output: str = "binary", device=None) -> jax.Array:
    """Deterministic-given-key forward through ONE sampled chip.

    x_ext: [..., rows] word-line bits with always-on rows already prefixed;
    ep/en: effective conductances (variation/leak applied); gp/gn: binary LRS
    placement planes (ideal counts).  This is the function `repro.mc` vmaps
    over a leading chips axis — all chip identity lives in (k_sa, ep, en).

    output: "binary" — SA decisions; "diff" — raw analog difference (ideal
    readout, for calibration); "sensed_diff" — the difference the periphery
    reports, with per-macro SA offset and sensing-range failures applied
    (what a digital combiner of multi-macro layers receives).

    `device`: the `repro.device` backend for periphery statistics (SA
    offset sigma, IR-drop factors); variation is already baked into ep/en
    by `sample_chip_planes` — pass the SAME backend to both.
    """
    blk = spec.ir_block
    i_pos, p_pos = _accumulate(_block_reduce(x_ext, ep, blk),
                               _block_reduce(x_ext, gp, blk),
                               cfg, spec, accumulation, partial_rows, device)
    i_neg, p_neg = _accumulate(_block_reduce(x_ext, en, blk),
                               _block_reduce(x_ext, gn, blk),
                               cfg, spec, accumulation, partial_rows, device)
    if output == "diff":
        return i_pos - i_neg
    p_pair = p_pos + p_neg
    if output == "sensed_diff":
        return ni.sensed_diff(k_sa, i_pos, i_neg, p_pair, cfg, spec,
                              sa_extra_units, device)
    return ni.resolve_sa(k_sa, i_pos, i_neg, p_pair, cfg, spec,
                         sa_extra_units, device)


def crossbar_forward(key: jax.Array, x_bits: jax.Array, mapped: MappedLayer,
                     *, cfg: ni.NonidealConfig = ni.NonidealConfig.none(),
                     spec: MacroSpec = DEFAULT_MACRO,
                     accumulation: str = "single_shot",
                     partial_rows: int = 256,
                     sa_extra_units: float = 0.0,
                     output: str = "binary", device=None) -> jax.Array:
    """Full structural crossbar simulation (sample one chip, then run it).

    x_bits: [..., fan_in] in {0,1}; returns [..., n_out]:
      output="binary": SA decisions in {0,1}
      output="diff":   analog current difference (for calibration / heads)

    Layers wider than the macro are tiled over multiple macros by the caller
    (see `IRCLinear`): this function simulates ONE macro's rows and asserts
    the planes fit.  Population studies should use `repro.mc`, which samples
    the chip state once per die and amortizes this forward over a chips axis.
    `device` selects the `repro.device` backend for BOTH the chip sampling
    and the periphery (None: analytic, bit-identical to the legacy path).
    """
    assert mapped.rows <= spec.rows, (
        f"planes ({mapped.rows} rows) exceed the macro ({spec.rows}); tile first")
    ep, en, k_sa = sample_chip_planes(key, mapped.g_pos, mapped.g_neg,
                                      mapped.scheme, cfg, spec, device)
    x_ext = extend_inputs(x_bits.astype(jnp.float32), mapped)
    return crossbar_apply(k_sa, x_ext, ep, en, mapped.g_pos, mapped.g_neg,
                          cfg=cfg, spec=spec, accumulation=accumulation,
                          partial_rows=partial_rows,
                          sa_extra_units=sa_extra_units, output=output,
                          device=device)


# ------------------------------------------------------------------ QAT surrogate

def variation_noise_std(p: jax.Array, sigma: float) -> jax.Array:
    """First-order std of a p-cell accumulated current under per-cell
    log-normal variation: sqrt(p) * std(lognormal(0, sigma))."""
    s2 = sigma * sigma
    cell_var = (jnp.exp(s2) - 1.0) * jnp.exp(s2)
    return jnp.sqrt(jnp.maximum(p, 0.0) * cell_var)


def irc_linear_train(key: jax.Array, x: jax.Array, w_latent: jax.Array, *,
                     cfg: ni.NonidealConfig = ni.NonidealConfig.none(),
                     spec: MacroSpec = DEFAULT_MACRO,
                     scheme: str = "ternary",
                     binarize_input: bool = True,
                     sa_beta: float = 4.0,
                     output: str = "binary") -> jax.Array:
    """Differentiable QAT path: quantized matmul + reparametrized noise.

    Matches the structural sim to first order: the current-difference noise
    from device variation has std sqrt(p_pair)*std_cell and the SA offset has
    std 0.5*g(p_pair); both are added to the pre-activation with fresh
    samples per step (variation-aware training, paper Sec. V / ref [5]).
    """
    if binarize_input:
        x = binary_activation(x)
    if scheme == "ternary":
        w_q = ternary_quantize(w_latent)
    elif scheme == "binary":
        w_q = binary_quantize(w_latent)
    else:
        raise ValueError(scheme)
    pre = x @ w_q
    if cfg.any():
        k1, k2 = jax.random.split(key)
        # expected activated-LRS count on the differential pair
        lrs_frac = jnp.mean(jnp.abs(jax.lax.stop_gradient(w_q)))
        p_pair = jnp.sum(jax.lax.stop_gradient(x), axis=-1, keepdims=True) * lrs_frac
        std = 0.0
        if cfg.device_variation:
            std = std + variation_noise_std(p_pair, spec.sigma_lrs)
        if cfg.sa_variation:
            std = std + 0.5 * ni.sa_required_diff(p_pair, spec)
        if cfg.device_variation or cfg.sa_variation:
            pre = pre + std * jax.random.normal(k1, pre.shape, pre.dtype)
    if output == "diff":
        return pre
    return soft_sa_output(pre, beta=sa_beta)


# ------------------------------------------------------------------ layer module

@dataclasses.dataclass(frozen=True)
class IRCLinearConfig:
    """Static configuration of one IRCLinear layer: shape, weight scheme,
    accumulation mode, and output stage."""
    fan_in: int
    fan_out: int
    scheme: str = "ternary"             # "ternary" (proposed) | "binary" (baseline)
    bias_rows: int = 0                  # extra common-mode bias rows (<= 32)
    accumulation: str = "single_shot"   # "single_shot" | "partial_sum"
    partial_rows: int = 256
    use_bn: bool = False                # baseline in-memory BN (Fig. 13a)
    output: str = "binary"              # "binary" | "diff"


class IRCLinear:
    """A linear layer executable ideally, via QAT surrogate, or through the
    full crossbar simulation; fan-in wider than one macro is tiled over
    multiple macros whose analog differences combine digitally (per-tile
    nonideal effects still apply)."""

    def __init__(self, config: IRCLinearConfig, spec: MacroSpec = DEFAULT_MACRO):
        self.config = config
        self.spec = spec

    def init(self, key: jax.Array) -> dict:
        """Initialize float parameters: fan-in-scaled Gaussian weights, plus
        identity BN statistics when `use_bn` is set."""
        c = self.config
        k_w, k_bn = jax.random.split(key)
        scale = 1.0 / jnp.sqrt(jnp.asarray(c.fan_in, jnp.float32))
        params = {"w": jax.random.normal(k_w, (c.fan_in, c.fan_out),
                                         jnp.float32) * scale}
        if c.use_bn:
            params["bn"] = {
                "gamma": jnp.ones((c.fan_out,), jnp.float32),
                "beta": jnp.zeros((c.fan_out,), jnp.float32),
                "mean": jnp.zeros((c.fan_out,), jnp.float32),
                "var": jnp.ones((c.fan_out,), jnp.float32),
            }
        return params

    def quantized_weights(self, params: dict) -> jax.Array:
        """Deployed weights under the configured scheme: ternary {-1,0,+1}
        (proposed) or binary {-1,+1} (baseline), straight-through in train."""
        if self.config.scheme == "ternary":
            return ternary_quantize(params["w"])
        return binary_quantize(params["w"])

    def map_to_planes(self, params: dict):
        """Build per-tile MappedLayers (static per deployment)."""
        from repro.core import mapping as mp
        c, spec = self.config, self.spec
        w_q = jax.lax.stop_gradient(self.quantized_weights(params))
        if c.scheme == "ternary":
            full = mp.ternary_planes(w_q, bias_rows=c.bias_rows)
        else:
            bn_units = None
            if c.use_bn:
                bn = params["bn"]
                bn_units = mp.fold_bn_to_bias_units(bn["gamma"], bn["beta"],
                                                    bn["mean"], bn["var"])
            full = mp.binary_planes(w_q, bn_bias_units=bn_units, spec=spec)
        lead = full.rows - full.fan_in   # always-on bias / BN rows (tile 0 only)
        tiles = []
        for lo in range(0, full.rows, spec.rows):
            hi = min(lo + spec.rows, full.rows)
            tile_lead = max(0, lead - lo) if lo < lead else 0
            tiles.append(MappedLayer(
                g_pos=full.g_pos[lo:hi], g_neg=full.g_neg[lo:hi],
                bias_rows=tile_lead, scheme=full.scheme,
                fan_in=(hi - lo) - tile_lead))
        return tiles

    def apply(self, params: dict, x: jax.Array, *, key: jax.Array,
              mode: str = "train",
              cfg: ni.NonidealConfig = ni.NonidealConfig.none(),
              sa_extra_units: float = 0.0) -> jax.Array:
        """Run the layer: `mode="train"` uses the differentiable QAT
        surrogate; `mode="eval"` runs the tiled structural crossbar sim."""
        c, spec = self.config, self.spec
        if mode == "train":
            return irc_linear_train(key, x, params["w"], cfg=cfg, spec=spec,
                                    scheme=c.scheme, output=c.output)
        # evaluation: full structural sim, tiled over macros.  Multi-tile
        # layers combine PER-TILE SENSED differences digitally: each macro's
        # SA front-end applies its own offset and sensing-range failures
        # before the combine ("diff" output stays the ideal analog readout
        # for calibration/heads).
        x_bits = jnp.where(x > 0, 1.0, 0.0).astype(jnp.float32)
        tiles = self.map_to_planes(params)
        multi = len(tiles) > 1
        tile_out = ("diff" if c.output == "diff"
                    else ("sensed_diff" if multi else "binary"))
        diffs = []
        offset = 0
        for t, tile in enumerate(tiles):
            k_t = jax.random.fold_in(key, t)
            lead = tile.rows - tile.fan_in
            x_t = x_bits[..., offset:offset + tile.rows - lead]
            offset += tile.rows - lead
            diffs.append(crossbar_forward(
                k_t, x_t, tile, cfg=cfg, spec=spec,
                accumulation=c.accumulation, partial_rows=c.partial_rows,
                sa_extra_units=sa_extra_units, output=tile_out))
        if not multi:
            return diffs[0]
        total = sum(diffs)
        if c.output == "diff":
            return total
        return (total > 0).astype(jnp.float32)


def ideal_ternary_matmul(x_bits: jax.Array, w_t: jax.Array) -> jax.Array:
    """Ideal digital reference: {0,1} inputs x ternary weights."""
    return x_bits.astype(jnp.float32) @ w_t.astype(jnp.float32)
