"""Weight -> crossbar conductance-plane mapping (paper Sec. IV-B, Figs. 12-13).

Two mapping schemes:

  * `ternary_planes`  (proposed): each weight column maps to a differential
    (G+, G-) bit-line pair; +1 -> (LRS, HRS), -1 -> (HRS, LRS), 0 -> (HRS, HRS).
  * `binary_planes`   (baseline): weights in {-1,+1} map to a single
    convolution bit-line (LRS for +1, HRS for -1) compared against a shared
    reference bit-line with alternating LRS/HRS (expected current = p/2).

Row-order matters because of IR drop: block 0 is closest to the bit-line
driver.  The proposed design places the (<=32) extra bias rows nearest the
driver (Fig. 13b); the baseline burns 96 near-driver rows on in-memory BN
(Fig. 13a).  Layers wider than the macro are tiled over multiple macros.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.macro import MacroSpec, DEFAULT_MACRO


@dataclasses.dataclass
class MappedLayer:
    """A linear layer mapped onto crossbar conductance planes.

    g_pos/g_neg: [rows_mapped, n_out] float {0,1} conductance planes, row 0
    nearest the driver.  `bias_rows` leading rows are always-on common-mode
    bias (LRS on BOTH planes) — they raise min(I+, I-) above the SA's lower
    sensing bound without changing the differential (Sec. IV-B.4).
    `bn_pos/bn_neg` leading rows (baseline only) encode the in-memory BN bias
    on one plane.
    """
    g_pos: jax.Array
    g_neg: jax.Array
    bias_rows: int
    scheme: str                    # "ternary" | "binary"
    fan_in: int

    @property
    def rows(self) -> int:
        """Total mapped rows, bias/BN rows included."""
        return self.g_pos.shape[0]

    @property
    def n_out(self) -> int:
        """Number of output columns (bit-lines)."""
        return self.g_pos.shape[1]


def ternary_planes(w_t: jax.Array, bias_rows: int = 0) -> MappedLayer:
    """Map ternary weights [fan_in, n_out] to differential planes.

    Returns planes of shape [bias_rows + fan_in, n_out]; bias rows first
    (nearest driver, Fig. 13b), then the weight rows.
    """
    w_t = w_t.astype(jnp.float32)
    g_pos = (w_t > 0.5).astype(jnp.float32)
    g_neg = (w_t < -0.5).astype(jnp.float32)
    if bias_rows:
        ones = jnp.ones((bias_rows, w_t.shape[1]), jnp.float32)
        g_pos = jnp.concatenate([ones, g_pos], axis=0)
        g_neg = jnp.concatenate([ones, g_neg], axis=0)
    return MappedLayer(g_pos=g_pos, g_neg=g_neg, bias_rows=bias_rows,
                       scheme="ternary", fan_in=w_t.shape[0])


def binary_planes(w_b: jax.Array, bn_bias_units: Optional[jax.Array] = None,
                  spec: MacroSpec = DEFAULT_MACRO) -> MappedLayer:
    """Baseline mapping: binary weights vs a shared reference bit-line.

    `g_pos` is the convolution bit-line (LRS for +1), `g_neg` the SHARED
    reference bit-line: evenly distributed half conductance so that ideally
    I_ref = p/2 for p activated rows and sign(I_conv - I_ref) = sign(x.w).
    Because ONE physical reference line serves the whole array (Fig. 12a),
    its variation / IR-drop error is a COMMON, input-dependent offset on
    every output channel — exactly the fragility the paper's Sec. IV-B.1
    calls out (the structural sim shares one variation column for it).
    If `bn_bias_units` [n_out] is given (integer units in [-bn_rows,
    bn_rows]), the in-memory BN mapping of Fig. 13a adds `spec.bn_rows`
    always-on leading rows: |b| of them LRS on the conv line (b>0) or on the
    reference line (b<0).
    """
    w_b = w_b.astype(jnp.float32)
    fan_in, n_out = w_b.shape
    conv = (w_b > 0).astype(jnp.float32)
    ref = jnp.full((fan_in, n_out), 0.5, jnp.float32)
    bn = 0
    if bn_bias_units is not None:
        bn = spec.bn_rows
        b = jnp.clip(jnp.round(bn_bias_units), -bn, bn)
        r = jnp.arange(bn, dtype=jnp.float32)[:, None]
        conv_bn = (r < jnp.maximum(b, 0)[None, :]).astype(jnp.float32)
        ref_bn = (r < jnp.maximum(-b, 0)[None, :]).astype(jnp.float32)
        conv = jnp.concatenate([conv_bn, conv], axis=0)
        ref = jnp.concatenate([ref_bn, ref], axis=0)
    return MappedLayer(g_pos=conv, g_neg=ref, bias_rows=bn,
                       scheme="binary", fan_in=fan_in)


def extend_inputs(x_bits: jax.Array, mapped: MappedLayer) -> jax.Array:
    """Prefix the always-on rows (bias / BN) to a batch of word-line patterns.

    x_bits: [..., fan_in] in {0,1}  ->  [..., rows]."""
    lead = mapped.rows - mapped.fan_in
    if lead == 0:
        return x_bits
    ones = jnp.ones(x_bits.shape[:-1] + (lead,), x_bits.dtype)
    return jnp.concatenate([ones, x_bits], axis=-1)


def tile_rows(mapped: MappedLayer, spec: MacroSpec = DEFAULT_MACRO
              ) -> Tuple[jax.Array, jax.Array, int]:
    """Split planes into macro-row tiles [n_tiles, spec.rows, n_out] (zero
    padded).  Tiles are separate macros: each accumulates analog internally
    and tile outputs are combined digitally (fan-in > macro rows cannot share
    a bit-line)."""
    rows, n_out = mapped.g_pos.shape
    n_tiles = -(-rows // spec.rows)
    pad = n_tiles * spec.rows - rows
    gp = jnp.pad(mapped.g_pos, ((0, pad), (0, 0))).reshape(n_tiles, spec.rows, n_out)
    gn = jnp.pad(mapped.g_neg, ((0, pad), (0, 0))).reshape(n_tiles, spec.rows, n_out)
    return gp, gn, n_tiles


def pad_inputs_for_tiles(x_ext: jax.Array, n_tiles: int,
                         spec: MacroSpec = DEFAULT_MACRO) -> jax.Array:
    """[..., rows] -> [..., n_tiles, spec.rows] matching `tile_rows`."""
    rows = x_ext.shape[-1]
    pad = n_tiles * spec.rows - rows
    x = jnp.pad(x_ext, [(0, 0)] * (x_ext.ndim - 1) + [(0, pad)])
    return x.reshape(x_ext.shape[:-1] + (n_tiles, spec.rows))


def fold_bn_to_bias_units(gamma: jax.Array, beta: jax.Array, mean: jax.Array,
                          var: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Fold BN into equivalent pre-activation bias units for in-memory BN.

    For binary activation sign(BN(y)) with gamma>0:
      sign(gamma*(y-mean)/std + beta) = sign(y + (beta*std/gamma - mean))
    The returned units are rounded to integer LRS cells by `binary_planes`
    (this rounding is exactly the BN-precision fragility the paper removes).
    """
    std = jnp.sqrt(var + eps)
    return beta * std / jnp.maximum(gamma, 1e-6) - mean
