"""Layerwise extra-bias calibration (paper Sec. IV-B.4, Table I).

The extra common-mode bias rows lift `min(I+, I-)` above the SA's lower
sensing bound — but more always-on LRS cells also enlarge the SA's
input-referred offset (Fig. 9), so bias choice is a per-layer trade-off.
`calibrate_bias` sweeps candidate bias values against a calibration batch of
bit-line current pairs and picks the bias minimizing the total expected error
rate, reproducing Table I's two error components:

    sensing-variation errors : |I+ - I-| too small vs the offset at p_pair
    below-lower-bound errors : min(I+, I-) + bias < sense_low
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.macro import MacroSpec, DEFAULT_MACRO
from repro.core import nonideal as ni


def sa_error_rates(i_pos: jax.Array, i_neg: jax.Array, p_pair: jax.Array,
                   bias_units: float, spec: MacroSpec = DEFAULT_MACRO
                   ) -> Dict[str, jax.Array]:
    """Expected error components for one candidate bias.

    i_pos/i_neg: calibration-batch bit-line currents WITHOUT bias ([...]);
    p_pair: activated LRS count on the pair (bias cells add 2*bias_units).
    Returns scalar rates in [0,1] (analytic expectations, no sampling):
      - `sensing_variation`: P(offset flips the decision) under the Gaussian
        offset model with std 0.5*g(p);
      - `below_lower_bound`: fraction with min(I+,I-)+bias below sense_low;
      - `above_upper_bound`: fraction exceeding sense_high (ternary 20% LRS
        keeps this at ~0, the paper's upper-limit argument).
    """
    b = jnp.asarray(bias_units, jnp.float32)
    ip, in_ = i_pos + b, i_neg + b
    p = p_pair + 2.0 * b
    diff = jnp.abs(ip - in_)
    sigma = 0.5 * ni.sa_required_diff(p, spec)
    # P(|N(0,sigma)| > diff) = 2*(1 - Phi(diff/sigma))
    flip = 2.0 * (1.0 - jax.scipy.stats.norm.cdf(diff / jnp.maximum(sigma, 1e-9)))
    low = (jnp.minimum(ip, in_) < spec.sense_low_units).astype(jnp.float32)
    high = (jnp.maximum(ip, in_) > spec.sense_high_units).astype(jnp.float32)
    return {
        "sensing_variation": jnp.mean(flip),
        "below_lower_bound": jnp.mean(low),
        "above_upper_bound": jnp.mean(high),
    }


def calibrate_bias(i_pos: jax.Array, i_neg: jax.Array, p_pair: jax.Array,
                   spec: MacroSpec = DEFAULT_MACRO,
                   candidates: Sequence[int] = (0, 4, 8, 12, 16, 20, 24, 28, 32),
                   ) -> Tuple[int, Dict[int, Dict[str, float]]]:
    """Pick the bias (in LRS units, <= spec.bias_rows_max) minimizing the
    total error rate on a calibration batch.  Returns (best_bias, report)
    where report[bias] carries the Table-I-style components."""
    report = {}
    best, best_err = 0, float("inf")
    for b in candidates:
        if b > spec.bias_rows_max:
            continue
        rates = sa_error_rates(i_pos, i_neg, p_pair, float(b), spec)
        rates = {k: float(v) for k, v in rates.items()}
        total = sum(rates.values())
        report[b] = dict(rates, total=total)
        if total < best_err:
            best, best_err = b, total
    return best, report


def layer_current_stats(key: jax.Array, x_bits: jax.Array, mapped,
                        spec: MacroSpec = DEFAULT_MACRO
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Collect (i_pos, i_neg, p_pair) for a calibration batch through one
    mapped layer, with device variation + IR drop active (the physical
    effects present when the SA samples the lines) but no periphery model."""
    from repro.core.crossbar import _block_reduce, _accumulate
    from repro.core.mapping import extend_inputs
    cfg = ni.NonidealConfig(device_variation=True, ir_drop=True)
    k_p, k_n = jax.random.split(key)
    x_ext = extend_inputs(x_bits.astype(jnp.float32), mapped)
    gp, gn = mapped.g_pos, mapped.g_neg
    ep = gp * ni.sample_variation_mask(k_p, gp.shape, spec.sigma_lrs)
    en = gn * ni.sample_variation_mask(k_n, gn.shape, spec.sigma_lrs)
    i_pos, p_pos = _accumulate(_block_reduce(x_ext, ep, spec.ir_block),
                               _block_reduce(x_ext, gp, spec.ir_block),
                               cfg, spec, "single_shot", 256)
    i_neg, p_neg = _accumulate(_block_reduce(x_ext, en, spec.ir_block),
                               _block_reduce(x_ext, gn, spec.ir_block),
                               cfg, spec, "single_shot", 256)
    return i_pos.ravel(), i_neg.ravel(), (p_pos + p_neg).ravel()
