"""IRC macro specification and power model.

The paper's macro: one 1024x1024 1T1R RRAM array (TSMC 40nm embedded RRAM),
all word-lines driven simultaneously, binary current-mode SAs (TMCSA [14])
comparing differential bit-line pairs. Key measured/designed constants:

  - word-line voltage 0.44 V  (chosen at the power/accuracy kink, Fig. 14)
  - LRS cell resistance ~1e5 ohm at 0.1 V across the cell  -> ~1 uA unit current
  - HRS = non-formed cell, >1e9 ohm -> ~1e-4 unit leakage, negligible variation
  - LRS log-normal resistance sigma ~= 0.4245 (log space) at WL=0.44 V (Fig. 3)
  - max bit-line current 300 uA; SA sensing window [35 uA, 300 uA]
  - IR-drop block model: 32-cell sub-blocks along the bit-line (Sec. III-E)
  - up to 32 extra bias rows (Fig. 13b); baseline in-memory BN used 96 rows

All currents in this package are normalized to "units" of one ideal LRS cell
current at the configured word-line voltage; `i_lrs_ua` converts back to uA
for the power model and for reporting against the paper's numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# (wl_voltage, unit LRS current uA, log-normal sigma of LRS current)
# 0.44 V / sigma 0.4245 are measured (paper Figs. 3, 14). Neighbouring points
# follow the paper's Fig. 14 sweep qualitatively (sub-threshold access FET:
# current rises ~exponentially with V_WL, variation shrinks); exact
# neighbouring sigmas are not published, so this table is our documented
# stand-in fit with the measured anchor point.
WL_OPERATING_POINTS: Tuple[Tuple[float, float, float], ...] = (
    (0.38, 0.22, 0.520),
    (0.40, 0.37, 0.480),
    (0.42, 0.61, 0.450),
    (0.44, 1.00, 0.4245),   # paper's chosen point (anchor, measured)
    (0.46, 1.65, 0.395),
    (0.48, 2.72, 0.370),
    (0.50, 4.48, 0.350),
)


def wl_point(wl_voltage: float) -> Tuple[float, float]:
    """Return (unit LRS current uA, log sigma) for a word-line voltage.

    Linear interpolation between tabulated operating points.
    """
    pts = WL_OPERATING_POINTS
    if wl_voltage <= pts[0][0]:
        return pts[0][1], pts[0][2]
    if wl_voltage >= pts[-1][0]:
        return pts[-1][1], pts[-1][2]
    for (v0, i0, s0), (v1, i1, s1) in zip(pts, pts[1:]):
        if v0 <= wl_voltage <= v1:
            t = (wl_voltage - v0) / (v1 - v0)
            return i0 + t * (i1 - i0), s0 + t * (s1 - s0)
    raise AssertionError("unreachable")


@dataclasses.dataclass(frozen=True)
class MacroSpec:
    """Physical description of one IRC macro (crossbar + periphery)."""

    rows: int = 1024                 # word-lines
    cols: int = 1024                 # bit-lines (512 differential pairs)
    wl_voltage: float = 0.44         # V
    v_read: float = 0.1              # V across the 1T1R cell during read
    sense_low_ua: float = 35.0       # SA lower sensing bound (per bit-line)
    sense_high_ua: float = 300.0     # max bit-line current / SA upper bound
    ir_block: int = 32               # cells per IR-drop sub-block
    # IR-drop coefficient: fractional current loss per (unit current x block
    # segment) of cumulative wire drop.  Calibrated so ~20% LRS occupancy of a
    # full column loses ~3-5% current at the far end, reproducing the paper's
    # Fig. 10 scale and the ~2x BN-vs-no-BN current-drop gap (Fig. 16).
    ir_alpha: float = 1.5e-5
    hrs_leak: float = 1e-4           # HRS cell current, in LRS units (1e9 vs 1e5 ohm)
    bias_rows_max: int = 32          # extra-bias rows (proposed design, Fig. 13b)
    bn_rows: int = 96                # rows the baseline burns on in-memory BN
    # SA sensing-variation fit (paper Fig. 9; coefficients not published, our
    # documented stand-in): required |I+ - I-| in units for a correct decision
    # grows with the number of activated LRS cells p on the compared pair:
    #   g(p) = sa_c0 + sa_c1 * p + sa_c2 * p**2
    # anchored at ~2 units for near-empty lines, ~8 units at p=300.
    sa_c0: float = 2.0
    sa_c1: float = 0.012
    sa_c2: float = 2.2e-5
    # direct LRS-sigma override for tolerance sweeps (Table IV); None ->
    # derived from the word-line operating point
    sigma_override: float = None

    @property
    def i_lrs_ua(self) -> float:
        """Mean LRS cell current (uA) at the word-line operating point."""
        return wl_point(self.wl_voltage)[0]

    @property
    def sigma_lrs(self) -> float:
        """LRS current sigma in LRS units (override wins over WL-derived)."""
        if self.sigma_override is not None:
            return self.sigma_override
        return wl_point(self.wl_voltage)[1]

    @property
    def sense_low_units(self) -> float:
        """Lower SA sensing bound expressed in LRS-current units."""
        return self.sense_low_ua / self.i_lrs_ua

    @property
    def sense_high_units(self) -> float:
        """Upper SA sensing bound expressed in LRS-current units."""
        return self.sense_high_ua / self.i_lrs_ua

    def with_wl_voltage(self, v: float) -> "MacroSpec":
        """Copy of this spec at a different word-line voltage (Fig. 7 sweep)."""
        return dataclasses.replace(self, wl_voltage=v)

    # ---------------------------------------------------------------- power
    def read_energy_pj(self, activated_lrs: float, t_sense_ns: float = 14.6) -> float:
        """Analog read energy (pJ) of one macro evaluation.

        P = sum(I_cell) * V_read + WL driver overhead; t_sense from the TMCSA
        reference design [14] (14.6 ns parallel MAC).  This is the model used
        to reproduce the Fig. 14 power/accuracy trade-off curve.
        """
        i_total_ua = activated_lrs * self.i_lrs_ua
        p_uw = i_total_ua * self.v_read + 0.05 * self.rows * self.wl_voltage
        return p_uw * t_sense_ns * 1e-3

    def macro_grid(self, fan_in: int, fan_out: int, bias_rows: int = 0) -> Tuple[int, int]:
        """(row_tiles, col_tiles) needed to map a (fan_in x fan_out) ternary
        layer with `bias_rows` extra rows; every weight needs a differential
        column pair, so a macro holds cols//2 output channels."""
        rows_needed = fan_in + bias_rows
        row_tiles = -(-rows_needed // self.rows)
        col_tiles = -(-fan_out // (self.cols // 2))
        return row_tiles, col_tiles


DEFAULT_MACRO = MacroSpec()
