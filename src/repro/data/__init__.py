from repro.data.lm import SyntheticLMData, lm_batch_for_step
from repro.data.detection import (SyntheticDetectionData, DetBatch,
                                  render_batch, yolo_targets)
