"""Synthetic object-detection dataset with IVS-3cls geometry.

The paper's dataset (IVS 3cls [17]: 10k traffic images, 3 classes — vehicle
/ bike / pedestrian, 1920x1080 rescaled to 1024x576) is not redistributable,
so we render a synthetic set with the same interface: images with 1-6
axis-aligned objects of 3 visually distinct classes (filled rectangles,
outlined rectangles, blobs) on structured noise backgrounds, plus YOLO grid
targets.  Deterministic per (seed, step): restart-exact, host-shardable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np
import jax.numpy as jnp

ANCHORS = np.array([[0.08, 0.12], [0.18, 0.25], [0.35, 0.45],
                    [0.5, 0.3], [0.75, 0.65]], np.float32)  # (w,h) fractions


@dataclasses.dataclass
class DetBatch:
    images: jnp.ndarray        # [B,H,W,3] float in [0,1]
    boxes: List[np.ndarray]    # per image [n,4] (cx,cy,w,h) fractions
    classes: List[np.ndarray]  # per image [n] int
    targets: Dict[str, jnp.ndarray]


@dataclasses.dataclass
class SyntheticDetectionData:
    img_hw: Tuple[int, int] = (64, 64)
    n_classes: int = 3
    n_anchors: int = 5
    stride: int = 8
    seed: int = 0

    def batch_for_step(self, step: int, batch: int) -> DetBatch:
        return render_batch(self.img_hw, batch, self.n_classes,
                            self.n_anchors, self.stride,
                            seed=(self.seed, step))


def _draw_object(img: np.ndarray, cls: int, box, rng) -> None:
    H, W, _ = img.shape
    cx, cy, w, h = box
    x0, x1 = int((cx - w / 2) * W), int((cx + w / 2) * W)
    y0, y1 = int((cy - h / 2) * H), int((cy + h / 2) * H)
    x0, y0 = max(x0, 0), max(y0, 0)
    x1, y1 = min(x1, W), min(y1, H)
    color = rng.random(3) * 0.5 + 0.5
    if cls == 0:      # "vehicle": filled rectangle
        img[y0:y1, x0:x1] = color
    elif cls == 1:    # "bike": outlined rectangle
        t = max(1, (y1 - y0) // 6)
        img[y0:y0 + t, x0:x1] = color
        img[y1 - t:y1, x0:x1] = color
        img[y0:y1, x0:x0 + t] = color
        img[y0:y1, x1 - t:x1] = color
    else:             # "pedestrian": bright vertical blob
        xm = (x0 + x1) // 2
        t = max(1, (x1 - x0) // 3)
        img[y0:y1, max(xm - t, 0):min(xm + t, W)] = color


def render_batch(img_hw, batch, n_classes=3, n_anchors=5, stride=8,
                 seed=(0, 0)) -> DetBatch:
    H, W = img_hw
    rng = np.random.default_rng(seed)
    images = rng.random((batch, H, W, 3)).astype(np.float32) * 0.15
    all_boxes, all_classes = [], []
    for b in range(batch):
        n = rng.integers(1, 7)
        boxes, classes = [], []
        for _ in range(n):
            w = rng.uniform(0.1, 0.5)
            h = rng.uniform(0.1, 0.5)
            cx = rng.uniform(w / 2, 1 - w / 2)
            cy = rng.uniform(h / 2, 1 - h / 2)
            cls = int(rng.integers(0, n_classes))
            _draw_object(images[b], cls, (cx, cy, w, h), rng)
            boxes.append([cx, cy, w, h])
            classes.append(cls)
        all_boxes.append(np.asarray(boxes, np.float32))
        all_classes.append(np.asarray(classes, np.int64))
    targets = yolo_targets(all_boxes, all_classes, (H // stride, W // stride),
                           n_anchors, n_classes)
    return DetBatch(images=jnp.asarray(images), boxes=all_boxes,
                    classes=all_classes,
                    targets={k: jnp.asarray(v) for k, v in targets.items()})


def _iou_wh(wh1, wh2) -> float:
    inter = min(wh1[0], wh2[0]) * min(wh1[1], wh2[1])
    return inter / (wh1[0] * wh1[1] + wh2[0] * wh2[1] - inter + 1e-9)


def yolo_targets(boxes: List[np.ndarray], classes: List[np.ndarray],
                 grid_hw: Tuple[int, int], n_anchors: int, n_classes: int
                 ) -> Dict[str, np.ndarray]:
    """YOLOv2-style targets: for each gt box, the best-IoU anchor in its
    grid cell is responsible."""
    B = len(boxes)
    gh, gw = grid_hw
    obj = np.zeros((B, gh, gw, n_anchors), np.float32)
    txywh = np.zeros((B, gh, gw, n_anchors, 4), np.float32)
    tcls = np.zeros((B, gh, gw, n_anchors), np.int64)
    for b in range(B):
        for box, cls in zip(boxes[b], classes[b]):
            cx, cy, w, h = box
            gx = min(int(cx * gw), gw - 1)
            gy = min(int(cy * gh), gh - 1)
            a = int(np.argmax([_iou_wh((w, h), tuple(A))
                               for A in ANCHORS[:n_anchors]]))
            obj[b, gy, gx, a] = 1.0
            txywh[b, gy, gx, a] = [cx * gw - gx, cy * gh - gy, w, h]
            tcls[b, gy, gx, a] = cls
    return {"obj": obj, "txywh": txywh, "cls": tcls}
