"""Deterministic synthetic LM token pipeline.

Stateless-seeded: batch(step) is a pure function of (seed, step), so a
restarted job resumes EXACTLY where it left off with no data-loader state in
the checkpoint — a fault-tolerance property, not a convenience.  Each host
materializes only its own shard (host-local loading), and the generated
stream has learnable n-gram structure so a few hundred training steps show a
real loss drop (used by examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_clusters: int = 64       # markov structure: vocab clusters

    def batch_for_step(self, step: int, host_id: int = 0,
                       n_hosts: int = 1) -> Dict[str, jax.Array]:
        return lm_batch_for_step(self.vocab_size, self.seq_len,
                                 self.global_batch, step, self.seed,
                                 self.n_clusters, host_id, n_hosts)


def lm_batch_for_step(vocab_size: int, seq_len: int, global_batch: int,
                      step: int, seed: int = 0, n_clusters: int = 64,
                      host_id: int = 0, n_hosts: int = 1
                      ) -> Dict[str, jax.Array]:
    """Markov-chain tokens: next token's cluster depends on the previous
    token's cluster (learnable structure), token within cluster uniform."""
    local_batch = global_batch // n_hosts
    rng = np.random.default_rng((seed, step, host_id))
    n_clusters = min(n_clusters, vocab_size)
    per = max(vocab_size // n_clusters, 1)
    # deterministic cluster-transition table from the seed
    trng = np.random.default_rng(seed)
    trans = trng.permutation(n_clusters)

    clusters = np.empty((local_batch, seq_len + 1), np.int64)
    clusters[:, 0] = rng.integers(0, n_clusters, local_batch)
    noise = rng.random((local_batch, seq_len)) < 0.1
    for t in range(seq_len):
        nxt = trans[clusters[:, t]]
        rand = rng.integers(0, n_clusters, local_batch)
        clusters[:, t + 1] = np.where(noise[:, t], rand, nxt)
    within = rng.integers(0, per, (local_batch, seq_len + 1))
    toks = np.minimum(clusters * per + within, vocab_size - 1)
    return {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
    }
