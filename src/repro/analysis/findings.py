"""Structured findings + committed-baseline semantics for `repro.analysis`.

A `Finding` is one rule violation at one source location.  Baselines
grandfather known violations: a committed `baseline.json` lists findings
that existed when the rule landed, and `--fail-on-new` (the default) exits
nonzero only on findings NOT in the baseline.  Baseline identity is
`(rule, file, message)` — deliberately line-number-free, so unrelated edits
above a grandfathered violation don't churn the baseline file.

The bit-exactness-critical subtrees (`repro/mc`, `repro/core`,
`repro/kernels`) must stay baseline-EMPTY: `assert_clean_subtrees` is the
enforcement hook the test suite pins.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Iterable, List, Sequence, Tuple

# Subtrees whose invariants back bit-identity guarantees; the committed
# baseline may never grandfather a finding inside them (tests pin this).
# serve/ is included: the serving engine's per-request committee results are
# promised bit-identical to run_mc_detector, so its key discipline is as
# load-bearing as the MC engine's.
CLEAN_SUBTREES = ("src/repro/mc", "src/repro/core", "src/repro/kernels",
                  "src/repro/serve", "src/repro/device")

BASELINE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation: where, what, and how to fix it."""
    rule: str          # e.g. "KEY001"
    file: str          # repo-relative posix path
    line: int          # 1-based; 0 when the finding is not line-anchored
    message: str       # one-line statement of the violation
    hint: str = ""     # fix hint shown next to the finding

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.file, self.message)

    def to_dict(self) -> Dict[str, object]:
        return {"rule": self.rule, "file": self.file, "line": self.line,
                "message": self.message, "hint": self.hint}

    def format(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else self.file
        hint = f" (hint: {self.hint})" if self.hint else ""
        return f"{loc} [{self.rule}] {self.message}{hint}"


def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (f.file, f.line, f.rule, f.message))


def load_baseline(path: Path) -> List[Finding]:
    if not path.exists():
        return []
    doc = json.loads(path.read_text())
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline version in {path}: "
                         f"{doc.get('version')!r}")
    return [Finding(rule=f["rule"], file=f["file"], line=int(f.get("line", 0)),
                    message=f["message"], hint=f.get("hint", ""))
            for f in doc.get("findings", [])]


def write_baseline(path: Path, findings: Sequence[Finding]) -> None:
    doc = {"version": BASELINE_VERSION,
           "findings": [f.to_dict() for f in sort_findings(findings)]}
    path.write_text(json.dumps(doc, indent=1) + "\n")


def split_by_baseline(findings: Sequence[Finding],
                      baseline: Sequence[Finding]
                      ) -> Tuple[List[Finding], List[Finding]]:
    """(new, grandfathered) partition of `findings` against `baseline`."""
    known = {f.key for f in baseline}
    new = [f for f in findings if f.key not in known]
    old = [f for f in findings if f.key in known]
    return new, old


def assert_clean_subtrees(baseline: Sequence[Finding]) -> List[str]:
    """Baseline entries inside the bit-exactness-critical subtrees (must be
    empty; returned as error strings for the caller to report)."""
    errors = []
    for f in baseline:
        if any(f.file.startswith(p + "/") or f.file == p
               for p in CLEAN_SUBTREES):
            errors.append(f"baseline grandfathers a finding in a "
                          f"bit-exactness-critical subtree: {f.format()}")
    return errors
