"""CLI: `python -m repro.analysis [paths...]`.

Exit codes: 0 clean (or every finding grandfathered / --no-fail-on-new),
1 non-baselined findings, 2 baseline integrity error (bad version, or a
grandfathered finding inside a bit-exactness-critical subtree).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.findings import (assert_clean_subtrees, load_baseline,
                                     split_by_baseline, write_baseline)
from repro.analysis.runner import DEFAULT_BASELINE, run_all


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static checks for key discipline (KEY*), trace "
                    "hygiene (TRC*) and shape contracts (SHP*).")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/dirs for the AST passes (default: src/)")
    ap.add_argument("--passes", default="keys,trace,contracts",
                    help="comma-separated subset of keys,trace,contracts")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="grandfathered-findings file "
                         "(default: %(default)s)")
    ap.add_argument("--fail-on-new", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="exit 1 when a finding is not in the baseline "
                         "(default: on)")
    ap.add_argument("--json", type=Path, default=None, metavar="OUT",
                    help="write findings + timing as JSON")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline to the current findings "
                         "and exit 0")
    args = ap.parse_args(argv)

    passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())
    bad = [p for p in passes if p not in ("keys", "trace", "contracts")]
    if bad:
        ap.error(f"unknown passes: {bad}")

    findings, timing = run_all(args.paths or None, passes=passes)

    try:
        baseline = load_baseline(args.baseline)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    clean_errors = assert_clean_subtrees(baseline)
    new, old = split_by_baseline(findings, baseline)

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    for f in new:
        print(f.format())
    for f in old:
        print(f"{f.format()}  [baselined]")
    for err in clean_errors:
        print(f"error: {err}", file=sys.stderr)

    per_pass = "  ".join(f"{k}={v:.2f}s" for k, v in timing.items()
                         if k != "total")
    print(f"repro.analysis: {len(findings)} finding(s) "
          f"({len(new)} new, {len(old)} baselined) in "
          f"{timing['total']:.2f}s  [{per_pass}]")

    if args.json:
        args.json.write_text(json.dumps(
            {"findings": [f.to_dict() for f in findings],
             "new": [f.to_dict() for f in new],
             "baselined": [f.to_dict() for f in old],
             "timing_s": timing}, indent=1) + "\n")

    if clean_errors:
        return 2
    if args.fail_on_new and new:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
