"""Shared AST plumbing for the analysis passes: import-alias resolution and
dotted-name extraction, so rules can match `jax.random.normal` whether it was
spelled that way or via `import jax.random as jr` / `from jax import random`.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Tuple


def dotted_name(node: ast.AST) -> Optional[str]:
    """`a.b.c` -> "a.b.c"; None for anything that is not a pure name chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def collect_import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Local name -> canonical dotted module/attribute path.

    `import jax.random as jr`      -> {"jr": "jax.random"}
    `from jax import random`       -> {"random": "jax.random"}
    `from jax.random import normal as nrm` -> {"nrm": "jax.random.normal"}
    `import jax`                   -> {"jax": "jax"}
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                local = a.asname or a.name.split(".")[0]
                aliases[local] = a.name if a.asname else a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def canonical(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Canonical dotted path of a name chain with import aliases expanded."""
    name = dotted_name(node)
    if name is None:
        return None
    head, _, rest = name.partition(".")
    if head in aliases:
        return aliases[head] + ("." + rest if rest else "")
    return name


def walk_functions(tree: ast.Module
                   ) -> Iterator[Tuple[str, ast.AST]]:
    """(qualname, FunctionDef/AsyncFunctionDef) for every function, with
    class nesting reflected in the qualname ("Class.method")."""
    def visit(node: ast.AST, prefix: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from visit(child, q + ".")
            elif isinstance(child, ast.ClassDef):
                yield from visit(child, f"{prefix}{child.name}.")
            else:
                yield from visit(child, prefix)
    yield from visit(tree, "")


def call_roots(expr: ast.AST, aliases: Dict[str, str]) -> Iterator[str]:
    """Canonical dotted paths of every Call's callee inside `expr`."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            path = canonical(node.func, aliases)
            if path is not None:
                yield path
