"""`repro.analysis` — static checks for the repo's reproducibility
invariants: PRNG key discipline (KEY*), jit/pallas trace hygiene (TRC*),
and whole-zoo shape contracts via `jax.eval_shape` (SHP*).

Run as `python -m repro.analysis` (see `--help`); CI runs it with
`--fail-on-new` against the committed `baseline.json`.
"""
from repro.analysis.findings import (CLEAN_SUBTREES, Finding,
                                     assert_clean_subtrees, load_baseline,
                                     sort_findings, split_by_baseline,
                                     write_baseline)
from repro.analysis.runner import DEFAULT_BASELINE, repo_root, run_all

__all__ = ["CLEAN_SUBTREES", "DEFAULT_BASELINE", "Finding",
           "assert_clean_subtrees", "load_baseline", "repo_root", "run_all",
           "sort_findings", "split_by_baseline", "write_baseline"]
