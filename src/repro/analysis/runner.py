"""Pass orchestration: discover files, run the three passes, time them."""
from __future__ import annotations

import time
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis.findings import Finding, sort_findings
from repro.analysis.keys import run_key_pass
from repro.analysis.registry import JIT_ENTRY_POINTS
from repro.analysis.trace import run_trace_pass


def repo_root() -> Path:
    """…/repo from …/repo/src/repro/analysis/runner.py."""
    return Path(__file__).resolve().parents[3]


DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def _iter_py_files(paths: Iterable[Path]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        if p.is_dir():
            out.extend(sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts))
        elif p.suffix == ".py":
            out.append(p)
    return out


def run_all(paths: Optional[List[Path]] = None, *,
            passes: Tuple[str, ...] = ("keys", "trace", "contracts"),
            ) -> Tuple[List[Finding], Dict[str, float]]:
    """Run the requested passes; (sorted findings, per-pass seconds).

    AST passes run over every .py under `paths` (default: src/ of this
    repo); the contract pass is path-independent — it abstract-evals the
    registries, so it runs whenever requested.
    """
    root = repo_root()
    if paths is None:
        paths = [root / "src"]
    files = _iter_py_files(paths)

    findings: List[Finding] = []
    timing: Dict[str, float] = {}

    def rel(p: Path) -> str:
        try:
            return p.resolve().relative_to(root).as_posix()
        except ValueError:
            return p.as_posix()

    if "keys" in passes:
        t0 = time.perf_counter()
        for f in files:
            findings.extend(run_key_pass(rel(f), f.read_text()))
        timing["keys"] = time.perf_counter() - t0
    if "trace" in passes:
        t0 = time.perf_counter()
        for f in files:
            roots = JIT_ENTRY_POINTS.get(rel(f), set())
            findings.extend(run_trace_pass(rel(f), f.read_text(), roots))
        timing["trace"] = time.perf_counter() - t0
    if "contracts" in passes:
        from repro.analysis.contracts import run_contract_pass
        t0 = time.perf_counter()
        findings.extend(run_contract_pass())
        timing["contracts"] = time.perf_counter() - t0
    timing["total"] = sum(timing.values())
    return sort_findings(findings), timing
