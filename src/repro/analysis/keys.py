"""Key-discipline pass (AST): PRNG-key reuse, nondeterministic key sources,
and fold_in lattice collisions.

The repo's bit-identity guarantees (ensemble chip `c` == the single-chip
`fold_in(key, c)` path; early-stopped MC == the full-run prefix;
`train_chips=1` == legacy QAT) are all statements about WHICH key reaches
which sampler.  This pass checks the statically-checkable part of that
discipline:

  KEY001  a key variable is consumed by two `jax.random.*` sampler calls
          without an intervening `split`/`fold_in` (including consumption
          inside a loop of a key created outside it — the classic
          same-noise-every-iteration bug).
  KEY002  a key is constructed from a nondeterministic source (wall clock,
          os.urandom, uuid, Python/NumPy global RNGs, id()/hash()): runs
          stop being reproducible from a recorded root key.
  KEY003  `fold_in` collision hazards: two call sites in one scope deriving
          the same subkey (same base, same constant salt), or an arithmetic
          salt lattice (e.g. `s * 10 + b`) whose multiplier is not in
          `DECLARED_FOLD_LATTICES` — undeclared lattices can silently
          collide when an index outgrows the multiplier.
  KEY004  a split result is stored into mutable object state
          (`self.key, sub = split(self.key)`): the key stream then advances
          with CALL ORDER, so draws depend on request arrival — the serving
          bug class this PR fixed in `repro.serve.engine`.

Passing a key to `split`/`fold_in` is a DERIVATION, not a consumption;
passing the same base key to many derivations is exactly the intended
discipline and is never flagged.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.analysis._astutil import (canonical, collect_import_aliases,
                                     dotted_name, walk_functions)
from repro.analysis.findings import Finding

# jax.random consumers: a key passed here is SPENT.
SAMPLERS = frozenset({
    "ball", "bernoulli", "beta", "binomial", "bits", "categorical", "cauchy",
    "chisquare", "choice", "dirichlet", "double_sided_maxwell", "exponential",
    "gamma", "generalized_normal", "geometric", "gumbel", "laplace",
    "loggamma", "logistic", "lognormal", "maxwell", "multivariate_normal",
    "normal", "orthogonal", "pareto", "permutation", "poisson", "rademacher",
    "randint", "rayleigh", "t", "triangular", "truncated_normal", "uniform",
    "wald", "weibull_min",
})

# jax.random derivations: a key passed here yields fresh subkeys.
DERIVERS = frozenset({"split", "fold_in", "clone"})

KEY_CONSTRUCTORS = frozenset({"PRNGKey", "key", "fold_in"})

# Nondeterministic sources that must never feed a PRNG key (exact canonical
# paths, or prefixes ending in ".").
NONDET_SOURCES = (
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "os.urandom", "os.getpid", "uuid.uuid1", "uuid.uuid4",
    "id", "hash",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
    "random.", "numpy.random.", "secrets.",
)

# Declared fold_in salt lattices: multiplier -> the invariant that keeps the
# lattice injective.  `s * 10 + b` is the detector's layer_id schedule
# (PR 2); `DetectorConfig.__post_init__` enforces blocks_per_stage < 10 so
# (s, b) -> s*10+b cannot collide.  New arithmetic salts must be declared
# here (with their runtime guard) or KEY003 flags them.
DECLARED_FOLD_LATTICES: Dict[int, str] = {
    10: "detector layer_id = stage*10 + block; DetectorConfig enforces "
        "blocks_per_stage < 10 (repro.models.detector)",
}


def _is_jax_random(path: Optional[str]) -> Optional[str]:
    """'jax.random.normal' -> 'normal'; None when not a jax.random member."""
    if path and path.startswith("jax.random."):
        tail = path[len("jax.random."):]
        if "." not in tail:
            return tail
    return None


@dataclasses.dataclass
class _Scope:
    """Per-function abstract state for the reuse analysis."""
    gen: Dict[str, int] = dataclasses.field(default_factory=dict)
    # (name, generation) -> consumption lines
    consumed: Dict[Tuple[str, int], List[int]] = dataclasses.field(
        default_factory=dict)
    # (name, generation) -> loop depth at which this generation was bound
    origin: Dict[Tuple[str, int], int] = dataclasses.field(
        default_factory=dict)

    def clone(self) -> "_Scope":
        return _Scope(gen=dict(self.gen),
                      consumed={k: list(v) for k, v in self.consumed.items()},
                      origin=dict(self.origin))

    def merge_branch(self, other: "_Scope") -> None:
        """Join of two exclusive branches: max consumption count per key."""
        for k, lines in other.consumed.items():
            mine = self.consumed.setdefault(k, [])
            if len(lines) > len(mine):
                self.consumed[k] = list(lines)
        for name, g in other.gen.items():
            self.gen[name] = max(self.gen.get(name, 0), g)
        for k, d in other.origin.items():
            self.origin.setdefault(k, d)


class KeyDisciplinePass:
    def __init__(self, path: str, source: str):
        self.path = path
        self.tree = ast.parse(source, filename=path)
        self.aliases = collect_import_aliases(self.tree)
        self.findings: List[Finding] = []

    # ------------------------------------------------------------- helpers
    def _member(self, call: ast.Call) -> Optional[str]:
        return _is_jax_random(canonical(call.func, self.aliases))

    def _key_arg(self, call: ast.Call) -> Optional[ast.AST]:
        if call.args:
            return call.args[0]
        for kw in call.keywords:
            if kw.arg == "key":
                return kw.value
        return None

    # ------------------------------------------------------------ KEY002/3
    def _check_call_rules(self, call: ast.Call, scope_desc: str,
                          fold_sites: Dict[Tuple[str, object],
                                           List[Tuple[int, int]]]) -> None:
        member = self._member(call)
        if member is None:
            return
        if member in KEY_CONSTRUCTORS:
            args = (call.args[1:] if member == "fold_in" else call.args)
            for arg in args:
                for node in ast.walk(arg):
                    if not isinstance(node, ast.Call):
                        continue
                    src = canonical(node.func, self.aliases)
                    if src is None:
                        continue
                    if any(src == p or (p.endswith(".") and
                                        src.startswith(p))
                           for p in NONDET_SOURCES):
                        self.findings.append(Finding(
                            rule="KEY002", file=self.path, line=node.lineno,
                            message=f"PRNG key in {scope_desc} is derived "
                                    f"from nondeterministic source "
                                    f"`{src}()`",
                            hint="seed keys from a recorded root "
                                 "(PRNGKey(seed) + fold_in of stable ids) "
                                 "so the run replays from its manifest"))
        if member == "fold_in" and call.args and len(call.args) >= 2:
            base = dotted_name(call.args[0])
            salt = call.args[1]
            if base is not None and isinstance(salt, ast.Constant) \
                    and isinstance(salt.value, int):
                fold_sites.setdefault((base, salt.value), []).append(
                    (call.lineno, call.col_offset))
            elif base is not None and isinstance(salt, ast.BinOp):
                self._check_lattice(salt, scope_desc)

    def _check_lattice(self, salt: ast.BinOp, scope_desc: str) -> None:
        """`a*C + b` salts must use a declared multiplier C."""
        mults: List[int] = []
        for node in ast.walk(salt):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
                for side in (node.left, node.right):
                    if isinstance(side, ast.Constant) \
                            and isinstance(side.value, int):
                        mults.append(side.value)
        declared = [m for m in mults if m in DECLARED_FOLD_LATTICES]
        if not declared:
            self.findings.append(Finding(
                rule="KEY003", file=self.path, line=salt.lineno,
                message=f"arithmetic fold_in salt in {scope_desc} uses an "
                        f"undeclared lattice "
                        f"(`{ast.unparse(salt)}`)",
                hint="declare the multiplier in repro.analysis.keys."
                     "DECLARED_FOLD_LATTICES with the runtime guard that "
                     "keeps the lattice injective (e.g. s*10+b needs "
                     "b < 10)"))

    # ------------------------------------------------------------- KEY001
    def _consume(self, scope: _Scope, name: str, line: int) -> None:
        g = scope.gen.get(name, 0)
        lines = scope.consumed.setdefault((name, g), [])
        lines.append(line)
        if len(lines) == 2:
            self.findings.append(Finding(
                rule="KEY001", file=self.path, line=line,
                message=f"key `{name}` consumed by a second jax.random "
                        f"sampler without an intervening split/fold_in "
                        f"(first use at line {lines[0]})",
                hint="derive one subkey per draw: k1, k2 = "
                     "jax.random.split(key) or fold_in(key, stable_id)"))

    def _scan_expr(self, expr: ast.AST, scope: _Scope,
                   fold_sites, scope_desc: str) -> None:
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            self._check_call_rules(node, scope_desc, fold_sites)
            member = self._member(node)
            if member in SAMPLERS:
                key_arg = self._key_arg(node)
                name = dotted_name(key_arg) if key_arg is not None else None
                if name is not None:
                    self._consume(scope, name, node.lineno)

    def _bind_targets(self, targets, scope: _Scope, depth: int,
                      value: Optional[ast.AST] = None) -> None:
        for t in targets:
            if isinstance(t, (ast.Tuple, ast.List)):
                self._bind_targets(t.elts, scope, depth, value)
            elif isinstance(t, ast.Name):
                g = scope.gen.get(t.id, 0) + 1
                scope.gen[t.id] = g
                scope.origin[(t.id, g)] = depth
            elif isinstance(t, ast.Attribute):
                name = dotted_name(t)
                if name is not None:
                    g = scope.gen.get(name, 0) + 1
                    scope.gen[name] = g
                    scope.origin[(name, g)] = depth
                if value is not None:
                    self._check_key004(t, value)

    def _check_key004(self, target: ast.Attribute, value: ast.AST) -> None:
        for node in ast.walk(value):
            if isinstance(node, ast.Call) and self._member(node) == "split":
                tname = dotted_name(target) or "<attr>"
                self.findings.append(Finding(
                    rule="KEY004", file=self.path, line=target.lineno,
                    message=f"split result stored into mutable state "
                            f"`{tname}`: the key stream advances with call "
                            f"order, so draws depend on request arrival",
                    hint="key draws by stable coordinates instead: "
                         "fold_in(root, wave)/fold_in(wave_key, step)"))
                return

    def _walk_stmts(self, stmts, scope: _Scope, depth: int,
                    fold_sites, scope_desc: str) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue   # nested defs are analyzed as their own scopes
            if isinstance(stmt, ast.Assign):
                self._scan_expr(stmt.value, scope, fold_sites, scope_desc)
                for t in stmt.targets:
                    self._bind_targets([t], scope, depth, stmt.value)
            elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
                if stmt.value is not None:
                    self._scan_expr(stmt.value, scope, fold_sites, scope_desc)
                self._bind_targets([stmt.target], scope, depth, stmt.value)
            elif isinstance(stmt, ast.If):
                self._scan_expr(stmt.test, scope, fold_sites, scope_desc)
                branch = scope.clone()
                self._walk_stmts(stmt.body, scope, depth, fold_sites,
                                 scope_desc)
                self._walk_stmts(stmt.orelse, branch, depth, fold_sites,
                                 scope_desc)
                scope.merge_branch(branch)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                self._scan_expr(stmt.iter, scope, fold_sites, scope_desc)
                self._bind_targets([stmt.target], scope, depth + 1)
                self._loop_body(stmt.body, scope, depth, fold_sites,
                                scope_desc)
                self._walk_stmts(stmt.orelse, scope, depth, fold_sites,
                                 scope_desc)
            elif isinstance(stmt, ast.While):
                self._scan_expr(stmt.test, scope, fold_sites, scope_desc)
                self._loop_body(stmt.body, scope, depth, fold_sites,
                                scope_desc)
                self._walk_stmts(stmt.orelse, scope, depth, fold_sites,
                                 scope_desc)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self._scan_expr(item.context_expr, scope, fold_sites,
                                    scope_desc)
                self._walk_stmts(stmt.body, scope, depth, fold_sites,
                                 scope_desc)
            elif isinstance(stmt, ast.Try):
                self._walk_stmts(stmt.body, scope, depth, fold_sites,
                                 scope_desc)
                for h in stmt.handlers:
                    self._walk_stmts(h.body, scope, depth, fold_sites,
                                     scope_desc)
                self._walk_stmts(stmt.orelse, scope, depth, fold_sites,
                                 scope_desc)
                self._walk_stmts(stmt.finalbody, scope, depth, fold_sites,
                                 scope_desc)
            elif isinstance(stmt, (ast.Expr, ast.Return)):
                if stmt.value is not None:
                    self._scan_expr(stmt.value, scope, fold_sites, scope_desc)
            elif isinstance(stmt, (ast.Assert, ast.Raise, ast.Delete)):
                for node in ast.iter_child_nodes(stmt):
                    self._scan_expr(node, scope, fold_sites, scope_desc)

    def _loop_body(self, body, scope: _Scope, depth: int, fold_sites,
                   scope_desc: str) -> None:
        """One symbolic pass over a loop body; afterwards any consumption of
        a key bound OUTSIDE the loop is a cross-iteration reuse (the loop
        replays the same draw every iteration)."""
        before = {k: len(v) for k, v in scope.consumed.items()}
        self._walk_stmts(body, scope, depth + 1, fold_sites, scope_desc)
        for (name, g), lines in scope.consumed.items():
            new = lines[before.get((name, g), 0):]
            if not new:
                continue
            if scope.origin.get((name, g), 0) <= depth and len(lines) == 1:
                # a single in-loop consumption of an outer key still repeats
                # per iteration; >=2 was already flagged by _consume
                self.findings.append(Finding(
                    rule="KEY001", file=self.path, line=new[0],
                    message=f"key `{name}` bound outside the loop is "
                            f"consumed inside it: every iteration replays "
                            f"the same draw",
                    hint="fold the loop index in: "
                         "jax.random.fold_in(key, i)"))

    # -------------------------------------------------------------- driver
    def run(self) -> List[Finding]:
        # module scope
        module_scope = _Scope()
        fold_sites: Dict[Tuple[str, object], List[Tuple[int, int]]] = {}
        self._walk_stmts(self.tree.body, module_scope, 0, fold_sites,
                         "<module>")
        self._flag_fold_collisions(fold_sites, "<module>")
        for qualname, fn in walk_functions(self.tree):
            scope = _Scope()
            for a in (*fn.args.posonlyargs, *fn.args.args,
                      *fn.args.kwonlyargs):
                scope.origin[(a.arg, 0)] = 0
            sites: Dict[Tuple[str, object], List[Tuple[int, int]]] = {}
            self._walk_stmts(fn.body, scope, 0, sites, f"`{qualname}`")
            self._flag_fold_collisions(sites, f"`{qualname}`")
        return self.findings

    def _flag_fold_collisions(self, fold_sites, scope_desc: str) -> None:
        for (base, salt), sites in fold_sites.items():
            if len(set(sites)) >= 2:
                line = sorted(set(sites))[1][0]
                self.findings.append(Finding(
                    rule="KEY003", file=self.path, line=line,
                    message=f"two call sites in {scope_desc} derive the "
                            f"same subkey fold_in({base}, {salt})",
                    hint="give each derivation a distinct salt (or hoist "
                         "the shared subkey into one binding)"))


def run_key_pass(path: str, source: str) -> List[Finding]:
    return KeyDisciplinePass(path, source).run()
