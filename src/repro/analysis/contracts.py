"""Shape-contract pass: abstract-eval every registered entry point.

`jax.eval_shape` traces the real code with ShapeDtypeStructs — zero FLOPs,
zero host<->device traffic — so the whole detector, the MC engines and the
QAT step are type-checked end to end in well under a second each.  Rules:

  SHP001  a contract raised while tracing (shape error, broken config,
          signature drift — whatever `eval_shape` surfaced)
  SHP002  the contract traced but the output shape/dtype/tree disagrees
          with the declared expectation
  SHP003  an arch marked "live" in `configs.registry.ARCH_STATUS` has no
          shape contract — live code the pass cannot vouch for
  SHP004  a registered arch missing from ARCH_STATUS — quarantine status
          must be EXPLICIT (the model-zoo satellite of this PR): the pass
          never silently skips an arch
"""
from __future__ import annotations

import traceback
from typing import List

from repro.analysis.findings import Finding
from repro.analysis.registry import shape_contracts

REGISTRY_FILE = "src/repro/configs/registry.py"
VALID_STATUSES = ("live", "legacy")


def run_contract_pass() -> List[Finding]:
    from repro.configs.registry import ARCH_STATUS, list_archs

    findings: List[Finding] = []
    known_archs = list(list_archs()) + ["yolo-irc"]
    for arch in known_archs:
        status = ARCH_STATUS.get(arch)
        if status not in VALID_STATUSES:
            findings.append(Finding(
                rule="SHP004", file=REGISTRY_FILE, line=0,
                message=f"arch {arch!r} has no liveness status "
                        f"(got {status!r})",
                hint="add it to ARCH_STATUS as 'live' or 'legacy' — the "
                     "shape pass never skips an arch silently"))
    for arch, status in ARCH_STATUS.items():
        if arch not in known_archs:
            findings.append(Finding(
                rule="SHP004", file=REGISTRY_FILE, line=0,
                message=f"ARCH_STATUS entry {arch!r} is not a registered "
                        f"arch",
                hint="remove the stale entry or register the arch"))

    contracts = shape_contracts()
    covered = {c.arch for c in contracts if c.arch}
    for arch in known_archs:
        if ARCH_STATUS.get(arch) == "live" and arch not in covered:
            findings.append(Finding(
                rule="SHP003", file=REGISTRY_FILE, line=0,
                message=f"live arch {arch!r} has no shape contract",
                hint="declare one in repro.analysis.registry."
                     "shape_contracts()"))

    for c in contracts:
        try:
            mismatch = c.run()
        except Exception as e:                        # noqa: BLE001
            tb = traceback.format_exc().strip().splitlines()[-1]
            findings.append(Finding(
                rule="SHP001", file=c.file, line=0,
                message=f"contract {c.name} raised under eval_shape: "
                        f"{type(e).__name__}: {e}".splitlines()[0][:300],
                hint=f"reproduce with jax.eval_shape on the declared spec "
                     f"({tb[:120]})"))
            continue
        if mismatch:
            findings.append(Finding(
                rule="SHP002", file=c.file, line=0,
                message=f"contract {c.name}: {mismatch}",
                hint="either the entry point or the declared spec is wrong "
                     "— fix the regression or update the contract"))
    return findings
