"""Trace-hygiene pass (AST): recompile and cache-miss hazards inside
jit/pallas-reachable code.

PR 4 split `compile_s` from steady-state rates; those numbers are only
meaningful if traced code doesn't silently retrace or sync to host.  This
pass computes the set of functions reachable from a `jax.jit` /
`pallas_call` root (decorators, `functools.partial(jax.jit, ...)`,
registered entry points in `repro.analysis.registry.JIT_ENTRY_POINTS`,
plus transitive same-module calls) and flags, inside that set:

  TRC101  Python `if`/`while` whose condition contains a `jax.numpy` /
          `jax.lax` call: under trace the condition is a tracer and either
          raises ConcretizationError or (via `static_argnames`) forces a
          retrace per value.
  TRC102  host syncs — `.item()`, `float()`/`int()`/`bool()` over a jnp
          expression, `np.asarray`/`np.array` on traced values: each one
          blocks dispatch and wrecks steady-state timing.
  TRC103  jit-boundary signature bugs: `static_argnames` naming a
          parameter that doesn't exist (the arg silently stays traced),
          and mutable default values (list/dict/set) on jitted functions
          (unhashable when static; aliased state when not).
  TRC104  a jit-reachable function reading a module-level mutable literal
          (dict/list/set): the value is baked in at trace time, so later
          mutation silently diverges from the compiled version.

Reachability is intentionally same-module: cross-module jit edges must be
declared in `JIT_ENTRY_POINTS` (see README "Static analysis").
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis._astutil import (canonical, collect_import_aliases,
                                     dotted_name, walk_functions)
from repro.analysis.findings import Finding

TRACED_CALL_PREFIXES = ("jax.numpy.", "jax.lax.", "jax.nn.", "jax.scipy.")
JIT_WRAPPERS = ("jax.jit", "jax.pmap", "jax.experimental.pjit.pjit")
PALLAS_CALL = "pallas_call"


def _is_jnp_rooted(expr: ast.AST, aliases) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            path = canonical(node.func, aliases)
            if path and path.startswith(TRACED_CALL_PREFIXES):
                return True
    return False


class TraceHygienePass:
    def __init__(self, path: str, source: str,
                 extra_roots: Optional[Set[str]] = None):
        self.path = path
        self.tree = ast.parse(source, filename=path)
        self.aliases = collect_import_aliases(self.tree)
        self.extra_roots = extra_roots or set()
        self.findings: List[Finding] = []
        self.functions: Dict[str, ast.AST] = dict(walk_functions(self.tree))

    # -------------------------------------------------------- reachability
    def _decorator_paths(self, fn) -> List[str]:
        paths = []
        for dec in fn.decorator_list:
            node = dec.func if isinstance(dec, ast.Call) else dec
            p = canonical(node, self.aliases)
            if p:
                paths.append(p)
            # functools.partial(jax.jit, ...) — look one level in
            if isinstance(dec, ast.Call) and p == "functools.partial" \
                    and dec.args:
                inner = canonical(dec.args[0], self.aliases)
                if inner:
                    paths.append(inner)
        return paths

    def _jit_kwargs(self, fn) -> List[ast.keyword]:
        kws = []
        for dec in fn.decorator_list:
            if not isinstance(dec, ast.Call):
                continue
            p = canonical(dec.func, self.aliases)
            if p in JIT_WRAPPERS or p == "functools.partial":
                kws.extend(dec.keywords)
        return kws

    def _roots(self) -> Set[str]:
        roots = set(self.extra_roots)
        for qualname, fn in self.functions.items():
            decs = self._decorator_paths(fn)
            if any(d in JIT_WRAPPERS for d in decs):
                roots.add(qualname)
        # kernels handed to pl.pallas_call(kernel, ...) anywhere
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                p = canonical(node.func, self.aliases)
                if p and p.split(".")[-1] == PALLAS_CALL:
                    for arg in node.args[:1]:
                        name = dotted_name(arg)
                        if name:
                            roots.update(q for q in self.functions
                                         if q == name or
                                         q.endswith("." + name.split(".")[-1])
                                         and name.startswith("self."))
                            if name in self.functions:
                                roots.add(name)
        return {r for r in roots if r in self.functions}

    def _local_callees(self, qualname: str) -> Set[str]:
        fn = self.functions[qualname]
        cls_prefix = qualname.rsplit(".", 1)[0] + "." if "." in qualname \
            else ""
        out = set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None:
                continue
            if name in self.functions:
                out.add(name)
            elif name.startswith("self.") and cls_prefix:
                m = cls_prefix + name[len("self."):]
                if m in self.functions:
                    out.add(m)
        return out

    def reachable(self) -> Set[str]:
        seen: Set[str] = set()
        stack = list(self._roots())
        while stack:
            q = stack.pop()
            if q in seen:
                continue
            seen.add(q)
            stack.extend(self._local_callees(q) - seen)
        return seen

    # -------------------------------------------------------------- rules
    def _check_body(self, qualname: str, fn: ast.AST) -> None:
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)) \
                    and _is_jnp_rooted(node.test, self.aliases):
                kw = "while" if isinstance(node, ast.While) else "if"
                self.findings.append(Finding(
                    rule="TRC101", file=self.path, line=node.lineno,
                    message=f"Python `{kw}` on a traced jnp value in "
                            f"jit-reachable `{qualname}`",
                    hint="use jnp.where / jax.lax.cond / jax.lax.select, "
                         "or hoist the decision to a static argument"))
            elif isinstance(node, ast.Call):
                self._check_host_sync(qualname, node)

    def _check_host_sync(self, qualname: str, call: ast.Call) -> None:
        path = canonical(call.func, self.aliases)
        if isinstance(call.func, ast.Attribute) and call.func.attr == "item" \
                and not call.args:
            self.findings.append(Finding(
                rule="TRC102", file=self.path, line=call.lineno,
                message=f".item() host sync in jit-reachable `{qualname}`",
                hint="keep values on device; sync once outside jit"))
        elif path in ("float", "int", "bool") and call.args \
                and _is_jnp_rooted(call.args[0], self.aliases):
            self.findings.append(Finding(
                rule="TRC102", file=self.path, line=call.lineno,
                message=f"{path}() over a jnp expression in jit-reachable "
                        f"`{qualname}` forces a host sync",
                hint="stay in jnp dtypes inside traced code"))
        elif path in ("numpy.asarray", "numpy.array"):
            self.findings.append(Finding(
                rule="TRC102", file=self.path, line=call.lineno,
                message=f"numpy conversion in jit-reachable `{qualname}` "
                        f"pulls the value to host",
                hint="use jnp.asarray, or move the conversion outside jit"))

    def _check_jit_boundary(self, qualname: str, fn: ast.AST) -> None:
        params = [a.arg for a in (*fn.args.posonlyargs, *fn.args.args,
                                  *fn.args.kwonlyargs)]
        for kw in self._jit_kwargs(fn):
            if kw.arg not in ("static_argnames", "static_argnums"):
                continue
            names: List[str] = []
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                names = [v.value]
            elif isinstance(v, (ast.Tuple, ast.List)):
                names = [e.value for e in v.elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, str)]
            for n in names:
                if n not in params:
                    self.findings.append(Finding(
                        rule="TRC103", file=self.path, line=fn.lineno,
                        message=f"static_argnames of `{qualname}` names "
                                f"`{n}` which is not a parameter — the "
                                f"argument silently stays traced",
                        hint="match static_argnames to the signature"))
        for default in (*fn.args.defaults, *fn.args.kw_defaults):
            if isinstance(default, (ast.Dict, ast.List, ast.Set)):
                self.findings.append(Finding(
                    rule="TRC103", file=self.path, line=default.lineno,
                    message=f"mutable default argument on jit-reachable "
                            f"`{qualname}`",
                    hint="default to None and build inside, or use a "
                         "frozen/hashable value"))

    def _mutable_globals(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for stmt in self.tree.body:
            if isinstance(stmt, ast.Assign) \
                    and isinstance(stmt.value, (ast.Dict, ast.List, ast.Set,
                                                ast.ListComp, ast.DictComp,
                                                ast.SetComp)):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        out[t.id] = stmt.lineno
        return out

    def _check_global_capture(self, qualname: str, fn: ast.AST,
                              mutables: Dict[str, int]) -> None:
        local: Set[str] = {a.arg for a in (*fn.args.posonlyargs,
                                           *fn.args.args,
                                           *fn.args.kwonlyargs)}
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    if isinstance(t, ast.Name):
                        local.add(t.id)
        reported: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                    and node.id in mutables and node.id not in local \
                    and node.id not in reported:
                reported.add(node.id)
                self.findings.append(Finding(
                    rule="TRC104", file=self.path, line=node.lineno,
                    message=f"jit-reachable `{qualname}` reads module-level "
                            f"mutable `{node.id}`: its value is baked in at "
                            f"trace time",
                    hint="pass it as an argument (static if config-like) or "
                         "freeze it into a tuple/immutable constant"))

    # -------------------------------------------------------------- driver
    def run(self) -> List[Finding]:
        reach = self.reachable()
        mutables = self._mutable_globals()
        for qualname in sorted(reach):
            fn = self.functions[qualname]
            self._check_body(qualname, fn)
            self._check_jit_boundary(qualname, fn)
            self._check_global_capture(qualname, fn, mutables)
        return self.findings


def run_trace_pass(path: str, source: str,
                   extra_roots: Optional[Set[str]] = None) -> List[Finding]:
    return TraceHygienePass(path, source, extra_roots).run()
