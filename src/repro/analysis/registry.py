"""Declared entry points for the analysis passes.

Two registries live here:

`JIT_ENTRY_POINTS` — cross-module jit roots for the trace-hygiene pass.
The pass discovers `@jax.jit` / `pallas_call` roots statically, but a
function that is only ever called FROM a jitted function in another module
(e.g. `IRCDetector.apply`, invoked by `repro.mc.detector_mc._ensemble_forward`)
is invisible to same-module call-graph reachability.  Declare those here:
file (repo-relative) -> set of function qualnames to treat as traced roots.

`shape_contracts()` — the shape-contract registry for the abstract-eval
pass.  Each `ShapeContract.run` builds abstract inputs (ShapeDtypeStructs),
runs the real entry point under `jax.eval_shape` (zero FLOPs, full tracing)
and returns None on success or a mismatch description.  Adding a new jit
entry point = appending one contract here (see README "Static analysis").

`configs.registry.ARCH_STATUS` decides which model-zoo archs the pass may
treat as quarantined: every registered arch MUST carry a status ("live"
archs need a contract below; "legacy" archs get a smoke-geometry eval_shape
so drift in quarantined code is still caught, just reported as legacy).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Set

JIT_ENTRY_POINTS: Dict[str, Set[str]] = {
    # called from repro.mc.detector_mc._ensemble_forward (jit) and the QAT
    # loss closure inside make_det_qat_step (grad+jit in callers)
    "src/repro/models/detector.py": {"IRCDetector.apply"},
    # called from _fused_chunk_metrics (jit) in the same package but via
    # from-import at function scope — declare rather than rely on luck
    "src/repro/mc/ensemble.py": {"sample_ensemble",
                                 "sample_ensemble_with_keys"},
    # crossbar forward is the body every jitted MC path inlines
    "src/repro/core/crossbar.py": {"crossbar_apply"},
    "src/repro/core/nonideal.py": {"resolve_sa", "sensed_diff"},
    # consulted at TRACE time by IRCDetector._gconv_ensemble's kernel
    # dispatch (static tuning-table lookups on concrete shapes) — keep the
    # hygiene checks on them even though they never see a tracer
    "src/repro/kernels/autotune.py": {"kernel_wins", "best_blocks", "lookup"},
    # device-model sampling hooks: invoked from sample_chip_planes (inlined
    # into every jitted MC sampling root) via the `device=` seam — the
    # call crosses a dispatch boundary the static call graph cannot follow
    "src/repro/device/analytic.py": {"AnalyticDeviceModel.variation_mask"},
    "src/repro/device/measured.py": {"MeasuredDeviceModel.variation_mask",
                                     "MeasuredDeviceModel.variation_factor"},
    "src/repro/device/retention.py": {"RetentionDrift.variation_mask"},
    "src/repro/device/base.py": {"DeviceModel.sa_offset_sigma",
                                 "DeviceModel.ir_drop_factors"},
}


@dataclasses.dataclass(frozen=True)
class ShapeContract:
    """One abstract-eval contract: `run()` returns None or a mismatch."""
    name: str           # e.g. "detector.apply[train,ternary-smoke]"
    file: str           # repo-relative file the contract protects
    run: Callable[[], Optional[str]]
    arch: Optional[str] = None   # registry arch this contract covers


def _struct(shape, dtype="float32"):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _expect(out, shape, dtype, what: str) -> Optional[str]:
    if tuple(out.shape) != tuple(shape):
        return f"{what}: expected shape {tuple(shape)}, got {tuple(out.shape)}"
    if str(out.dtype) != dtype:
        return f"{what}: expected dtype {dtype}, got {out.dtype}"
    return None


def _det_and_params(scheme: str):
    """Smoke-geometry detector + ABSTRACT params (init under eval_shape)."""
    import jax
    from repro.configs import yolo_irc
    from repro.models.detector import IRCDetector
    det = IRCDetector(yolo_irc.smoke(scheme))
    params = jax.eval_shape(det.init, _struct((2,), "uint32"))
    return det, params


def _det_head(det):
    return det.head_geometry()


def _contract_det_forward(scheme: str, mode: str) -> Optional[str]:
    import jax
    from repro.core import NonidealConfig
    det, params = _det_and_params(scheme)
    B = 2
    images = _struct((B, *det.cfg.img_hw, 3))
    cfg_ni = NonidealConfig.none() if mode == "train" else NonidealConfig.all()

    def fwd(p, x, k):
        return det.apply(p, x, mode=mode, key=k, cfg_ni=cfg_ni)
    out = jax.eval_shape(fwd, params, images, _struct((2,), "uint32"))
    gh, gw, ho = _det_head(det)
    return _expect(out, (B, gh, gw, ho), "float32",
                   f"detector.apply[{mode},{scheme}]")


def _contract_det_ensemble(n_chips: int,
                           use_kernel: Optional[bool] = None) -> Optional[str]:
    import jax
    from repro.core import NonidealConfig
    from repro.mc.detector_mc import build_detector_ensemble
    det, params = _det_and_params("ternary")
    B = 2
    images = _struct((B, *det.cfg.img_hw, 3))

    def fwd(p, x, k):
        ens = build_detector_ensemble(k, det, p, n_chips,
                                      cfg=NonidealConfig.all())
        return det.apply(p, x, mode="ensemble", ensemble=ens,
                         cfg_ni=NonidealConfig.all(), use_kernel=use_kernel)
    out = jax.eval_shape(fwd, params, images, _struct((2,), "uint32"))
    gh, gw, ho = _det_head(det)
    tag = ",kernel" if use_kernel else ""
    return _expect(out, (n_chips, B, gh, gw, ho), "float32",
                   f"detector.apply[ensemble x{n_chips}{tag}]")


def _contract_pipelined_chunk(n_chips: int) -> Optional[str]:
    """The pipelined sweep's fused chunk program: hoisted planes in, sampled
    ensemble + whole-network forward out, all under one trace."""
    import jax
    from repro.core import NonidealConfig
    from repro.mc.detector_mc import detector_planes, _sampled_chunk_forward
    det, params = _det_and_params("ternary")
    B = 2

    def fwd(p, x, k, ids):
        planes, meta = detector_planes(det, p)
        return _sampled_chunk_forward(
            p, x, k, ids, planes, det_cfg=det.cfg, spec=det.spec,
            cfg_ni=NonidealConfig.all(), sa_extra=0.0, meta=meta)
    out = jax.eval_shape(fwd, params, _struct((B, *det.cfg.img_hw, 3)),
                         _struct((2,), "uint32"),
                         _struct((n_chips,), "uint32"))
    gh, gw, ho = _det_head(det)
    return _expect(out, (n_chips, B, gh, gw, ho), "float32",
                   f"_sampled_chunk_forward[x{n_chips}]")


def _contract_committee_wave(slots: int, committee: int) -> Optional[str]:
    """The serving engine's wave program: [slots] request lanes, each an
    independent committee forward keyed by its own request key, one jitted
    dispatch -> [slots, chips, gh, gw, ho]."""
    import jax
    from repro.core import NonidealConfig
    from repro.mc.detector_mc import detector_planes, committee_wave_forward
    det, params = _det_and_params("ternary")

    def fwd(p, imgs, keys, ids):
        planes, meta = detector_planes(det, p)
        return committee_wave_forward(
            p, imgs, keys, ids, planes, det_cfg=det.cfg, spec=det.spec,
            cfg_ni=NonidealConfig.all(), sa_extra=0.0, meta=meta)
    out = jax.eval_shape(fwd, params,
                         _struct((slots, *det.cfg.img_hw, 3)),
                         _struct((slots, 2), "uint32"),
                         _struct((committee,), "uint32"))
    gh, gw, ho = _det_head(det)
    return _expect(out, (slots, committee, gh, gw, ho), "float32",
                   f"committee_wave_forward[s{slots},x{committee}]")


def _contract_qat_step(train_chips: int) -> Optional[str]:
    import jax
    from repro.optim import adamw_init
    from repro.train.steps import make_det_qat_step
    det, params = _det_and_params("ternary")
    opt = jax.eval_shape(adamw_init, params)
    step = make_det_qat_step(det, train_chips=train_chips)
    B = 2
    gh, gw, _ = _det_head(det)
    targets = {"txywh": _struct((B, gh, gw, det.cfg.n_anchors, 4)),
               "obj": _struct((B, gh, gw, det.cfg.n_anchors)),
               "cls": _struct((B, gh, gw, det.cfg.n_anchors), "int32")}
    out = jax.eval_shape(
        step, params, opt, _struct((B, *det.cfg.img_hw, 3)), targets,
        _struct((), "float32"), _struct((2,), "uint32"),
        _struct((2,), "uint32"))
    new_params, new_opt, loss = out
    for got, want, what in ((new_params, params, "params"),
                            (new_opt, opt, "opt")):
        got_td = jax.tree.structure(got)
        want_td = jax.tree.structure(want)
        if got_td != want_td:
            return (f"qat_step[chips={train_chips}]: {what} tree changed "
                    f"({want_td} -> {got_td})")
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            if a.shape != b.shape or a.dtype != b.dtype:
                return (f"qat_step[chips={train_chips}]: {what} leaf "
                        f"{b.shape}/{b.dtype} -> {a.shape}/{a.dtype}")
    return _expect(loss, (), "float32", f"qat_step[chips={train_chips}] loss")


def _contract_ensemble_apply(kernel: bool,
                             per_chip_x: bool = False,
                             device_name: Optional[str] = None,
                             t_days: float = 0.0) -> Optional[str]:
    import jax
    from repro.core import NonidealConfig
    from repro.core.mapping import ternary_planes
    from repro.mc import engine as mc_engine
    from repro.mc.ensemble import sample_ensemble
    device = None
    if device_name is not None:
        from repro.device import get_device_model
        device = get_device_model(device_name, t_days=t_days)
    n_chips, batch, fan_in, n_out, bias_rows = 3, 4, 60, 20, 16
    cfg = NonidealConfig.all()
    x_shape = ((n_chips, batch, fan_in) if per_chip_x
               else (batch, fan_in))

    def fwd(k, w, x):
        mapped = ternary_planes(w, bias_rows=bias_rows)
        ens = sample_ensemble(k, mapped, n_chips, cfg=cfg, device=device)
        if kernel:
            return mc_engine.ensemble_apply_kernel(ens, x, cfg=cfg,
                                                   per_chip_x=per_chip_x,
                                                   device=device)
        return mc_engine.ensemble_apply(ens, x, cfg=cfg,
                                        per_chip_x=per_chip_x, device=device)
    out = jax.eval_shape(fwd, _struct((2,), "uint32"),
                         _struct((fan_in, n_out)), _struct(x_shape))
    name = "ensemble_apply_kernel" if kernel else "ensemble_apply"
    if per_chip_x:
        name += "[per_chip_x]"
    if device is not None:
        name += f"[{device.name}]"
    return _expect(out, (n_chips, batch, n_out), "float32", name)


def _contract_device_sampling(device_name: str, t_days: float) -> Optional[str]:
    """The device-seam sampling roots: a measured / aged backend must sample
    the same ensemble geometry (planes shapes, key shapes) as the analytic
    path — backends change values, never shapes."""
    import jax
    from repro.core import NonidealConfig
    from repro.core.mapping import ternary_planes
    from repro.device import get_device_model
    from repro.mc.ensemble import sample_ensemble
    device = get_device_model(device_name, t_days=t_days)
    n_chips, fan_in, n_out, bias_rows = 3, 60, 20, 16
    rows = fan_in + bias_rows

    def fwd(k, w):
        mapped = ternary_planes(w, bias_rows=bias_rows)
        ens = sample_ensemble(k, mapped, n_chips, cfg=NonidealConfig.all(),
                              device=device)
        return ens.ep
    out = jax.eval_shape(fwd, _struct((2,), "uint32"),
                         _struct((fan_in, n_out)))
    return _expect(out, (n_chips, rows, n_out), "float32",
                   f"sample_ensemble[{device.name}]")


def _contract_ensemble_apply_donated() -> Optional[str]:
    """The chunk loop's buffer-donating entry (`run_mc`'s non-fused path):
    same output contract as `ensemble_apply`, ep/en/sa_keys donated."""
    import jax
    from repro.core import NonidealConfig
    from repro.core.macro import DEFAULT_MACRO
    from repro.core.mapping import ternary_planes
    from repro.mc.engine import _ensemble_apply_donated
    from repro.mc.ensemble import sample_ensemble
    n_chips, batch, fan_in, n_out, bias_rows = 3, 4, 60, 20, 16
    cfg = NonidealConfig.all()

    def fwd(k, w, x):
        mapped = ternary_planes(w, bias_rows=bias_rows)
        ens = sample_ensemble(k, mapped, n_chips, cfg=cfg)
        return _ensemble_apply_donated(
            ens.ep, ens.en, ens.sa_keys, ens.chip_ids, ens.gp, ens.gn,
            ens.bias_units, x, scheme=ens.scheme, fan_in=ens.fan_in,
            cfg=cfg, spec=DEFAULT_MACRO,
            accumulation="single_shot", partial_rows=256,
            sa_extra_units=0.0, backend="jnp")
    out = jax.eval_shape(fwd, _struct((2,), "uint32"),
                         _struct((fan_in, n_out)),
                         _struct((batch, fan_in)))
    return _expect(out, (n_chips, batch, n_out), "float32",
                   "_ensemble_apply_donated")


def _contract_fused_chunk_metrics() -> Optional[str]:
    import jax
    from repro.core import NonidealConfig
    from repro.core.macro import DEFAULT_MACRO
    from repro.mc.engine import _fused_chunk_metrics
    n_chips, batch, fan_in, n_out, bias_rows = 3, 4, 60, 20, 16
    rows = fan_in + bias_rows
    out = jax.eval_shape(
        lambda k, ids, x, gp, gn, ref: _fused_chunk_metrics(
            k, ids, x, gp, gn, ref, scheme="ternary", fan_in=fan_in,
            cfg=NonidealConfig.all(), spec=DEFAULT_MACRO,
            accumulation="single_shot", partial_rows=256,
            sa_extra_units=0.0),
        _struct((2,), "uint32"), _struct((n_chips,), "uint32"),
        _struct((batch, fan_in)), _struct((rows, n_out)),
        _struct((rows, n_out)), _struct((n_chips, batch, n_out)))
    for mname in ("bit_agreement", "ones_fraction"):
        if mname not in out:
            return f"_fused_chunk_metrics: missing metric {mname!r}"
        err = _expect(out[mname], (n_chips,), "float32",
                      f"_fused_chunk_metrics[{mname}]")
        if err:
            return err
    return None


def _contract_lm_smoke(arch: str) -> Optional[str]:
    import jax
    from repro.configs.registry import get_config
    from repro.models import LM
    cfg = get_config(arch, "smoke")
    lm = LM(cfg)
    params = jax.eval_shape(lm.init, _struct((2,), "uint32"))
    B, S = 2, 16
    toks = _struct((B, S), "int32")
    out = jax.eval_shape(lambda p, t: lm.apply(p, t, remat="none")[0],
                         params, toks)
    return _expect(out, (B, S, cfg.vocab_size), "float32",
                   f"LM.apply[{arch}-smoke]")


def shape_contracts() -> List[ShapeContract]:
    """Every declared entry-point contract, detector/MC first."""
    from repro.configs.registry import ARCH_STATUS, list_archs

    det_file = "src/repro/models/detector.py"
    mc_file = "src/repro/mc/engine.py"
    steps_file = "src/repro/train/steps.py"
    det = "yolo-irc"
    contracts = [
        ShapeContract("detector.apply[train,ternary]", det_file,
                      lambda: _contract_det_forward("ternary", "train"), det),
        ShapeContract("detector.apply[train,binary]", det_file,
                      lambda: _contract_det_forward("binary", "train"), det),
        ShapeContract("detector.apply[eval,ternary]", det_file,
                      lambda: _contract_det_forward("ternary", "eval"), det),
        ShapeContract("detector.apply[eval,binary]", det_file,
                      lambda: _contract_det_forward("binary", "eval"), det),
        ShapeContract("detector.apply[ensemble x4]", det_file,
                      lambda: _contract_det_ensemble(4), det),
        ShapeContract("detector.apply[ensemble x4,kernel]", det_file,
                      lambda: _contract_det_ensemble(4, use_kernel=True),
                      det),
        ShapeContract("_sampled_chunk_forward[x3]",
                      "src/repro/mc/detector_mc.py",
                      lambda: _contract_pipelined_chunk(3), det),
        ShapeContract("committee_wave_forward[s2,x3]",
                      "src/repro/mc/detector_mc.py",
                      lambda: _contract_committee_wave(2, 3), det),
        ShapeContract("qat_step[chips=1]", steps_file,
                      lambda: _contract_qat_step(1), det),
        ShapeContract("qat_step[chips=4]", steps_file,
                      lambda: _contract_qat_step(4), det),
        ShapeContract("ensemble_apply", mc_file,
                      lambda: _contract_ensemble_apply(False), det),
        ShapeContract("ensemble_apply_kernel", mc_file,
                      lambda: _contract_ensemble_apply(True), det),
        ShapeContract("ensemble_apply_kernel[per_chip_x]", mc_file,
                      lambda: _contract_ensemble_apply(True,
                                                       per_chip_x=True), det),
        ShapeContract("_ensemble_apply_donated", mc_file,
                      lambda: _contract_ensemble_apply_donated(), det),
        ShapeContract("_fused_chunk_metrics", mc_file,
                      lambda: _contract_fused_chunk_metrics(), det),
        ShapeContract("sample_ensemble[measured]",
                      "src/repro/device/measured.py",
                      lambda: _contract_device_sampling("measured", 0.0), det),
        ShapeContract("sample_ensemble[measured@t30d]",
                      "src/repro/device/retention.py",
                      lambda: _contract_device_sampling("measured", 30.0),
                      det),
        ShapeContract("ensemble_apply[measured]", mc_file,
                      lambda: _contract_ensemble_apply(
                          False, device_name="measured"), det),
        ShapeContract("ensemble_apply_kernel[measured@t30d]", mc_file,
                      lambda: _contract_ensemble_apply(
                          True, device_name="measured", t_days=30.0), det),
    ]
    for arch in list_archs():
        if ARCH_STATUS.get(arch) == "legacy":
            contracts.append(ShapeContract(
                f"LM.apply[{arch}-smoke] (legacy)",
                "src/repro/configs/registry.py",
                lambda a=arch: _contract_lm_smoke(a), arch))
    return contracts
