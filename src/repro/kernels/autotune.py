"""Block-shape autotuner for the chip-batched IRC kernel.

`irc_mvm_chips` is tiled by (bm, bn, bk) and the best block shape depends on
the problem geometry (chips, M, N, K) and the backend — on TPU the sweet
spot trades VMEM footprint against MXU utilization; on CPU the kernel runs
in interpret mode and (today) always loses to the vmapped jnp path.  Rather
than guess, `sweep()` times every candidate block shape against the
reference path (`repro.mc.ensemble_apply` on a sampled ensemble — the
exact code the detector falls back to) and commits the winners to
`tuning.json` next to this module.

The dispatch side is two lookups against that committed table:

  kernel_wins(C, M, N, K)   True iff a tuned entry for this backend and
                            problem says the kernel beat the reference path
                            (absent entry -> False: untuned problems stay on
                            the reference path, never a silent slow path)
  best_blocks(C, M, N, K)   the winning (bm, bn, bk), or the defaults

Table keys are `{backend}/c{C}_m{M}_n{N}_k{K}` — exact-match on the
problem, so a geometry change re-tunes rather than inheriting a stale
winner.  Re-run the sweep with:

  PYTHONPATH=src python -m repro.kernels.autotune --write \
      [--chips 8 --batch 2 --network detector]

`benchmarks/mc_bench.py` records the same sweep as roofline rows in
`BENCH_mc.json` (us + achieved GFLOP/s per candidate).
"""
from __future__ import annotations

import argparse
import functools
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

TUNING_JSON = Path(__file__).resolve().parent / "tuning.json"

DEFAULT_BLOCKS: Tuple[int, int, int] = (8, 128, 256)

# sublane/lane/ir-block aligned candidates (bm % 8, bn % 128, bk % 32 == 0);
# small enough that the VMEM scratch stays under budget at detector shapes
DEFAULT_CANDIDATES: Tuple[Tuple[int, int, int], ...] = (
    (8, 128, 256),
    (8, 128, 512),
    (16, 128, 256),
    (32, 128, 128),
)


def problem_key(C: int, M: int, N: int, K: int,
                backend: Optional[str] = None) -> str:
    backend = backend or jax.default_backend()
    return f"{backend}/c{C}_m{M}_n{N}_k{K}"


@functools.lru_cache(maxsize=1)
def load_table() -> Dict[str, dict]:
    """The committed tuning table (cached; `sweep(write=True)` invalidates)."""
    if not TUNING_JSON.exists():
        return {}
    try:
        return json.loads(TUNING_JSON.read_text())
    except json.JSONDecodeError:
        return {}


def lookup(C: int, M: int, N: int, K: int) -> Optional[dict]:
    return load_table().get(problem_key(C, M, N, K))


def kernel_wins(C: int, M: int, N: int, K: int) -> bool:
    """The auto-dispatch rule: route the kernel only where a committed sweep
    for THIS backend measured it faster than the reference path."""
    entry = lookup(C, M, N, K)
    return bool(entry and entry.get("use_kernel"))


def best_blocks(C: int, M: int, N: int, K: int) -> Tuple[int, int, int]:
    entry = lookup(C, M, N, K)
    if entry:
        return (int(entry["bm"]), int(entry["bn"]), int(entry["bk"]))
    return DEFAULT_BLOCKS


# ------------------------------------------------------------------ sweeping

def _median_us(fn, reps: int = 3) -> float:
    """Wall time of `fn()` (blocked): one warmup call, then the median."""
    jax.block_until_ready(fn())
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2] * 1e6


def _problem(C: int, M: int, N: int, K: int, seed: int = 0):
    """A synthetic ensemble problem of the given geometry: K-row ternary-ish
    placement planes (no bias rows — K IS the padded row count the kernel
    sees), a shared M-row word-line batch, and a C-chip sampled ensemble."""
    from repro.core.mapping import MappedLayer
    from repro.core import nonideal as ni
    from repro.mc.ensemble import sample_ensemble

    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    gp = (jax.random.uniform(k0, (K, N)) > 0.7).astype(jnp.float32)
    gn = (jax.random.uniform(k1, (K, N)) > 0.7).astype(jnp.float32) * (1 - gp)
    mapped = MappedLayer(g_pos=gp, g_neg=gn, bias_rows=0, scheme="ternary",
                         fan_in=K)
    x = (jax.random.uniform(k2, (M, K)) > 0.5).astype(jnp.float32)
    cfg = ni.NonidealConfig.all()
    ens = sample_ensemble(jax.random.PRNGKey(seed + 1), mapped, C, cfg=cfg)
    return ens, x, cfg


def autotune_problem(C: int, M: int, N: int, K: int, *,
                     candidates: Sequence[Tuple[int, int, int]]
                     = DEFAULT_CANDIDATES,
                     seed: int = 0) -> Tuple[dict, List[dict]]:
    """Time every candidate block shape and the reference path on one
    problem; returns (winner record, per-candidate roofline rows).

    FLOP accounting for the roofline rows: 4 MVM planes (ep/en currents +
    gp/gn counts) at 2*M*N*K flops each, per chip.
    """
    from repro.mc.engine import ensemble_apply, ensemble_apply_kernel

    ens, x, cfg = _problem(C, M, N, K, seed=seed)
    flops = 4 * 2.0 * C * M * N * K

    ref_us = _median_us(lambda: ensemble_apply(ens, x, cfg=cfg))
    rows = [{"impl": "ref", "bm": 0, "bn": 0, "bk": 0, "us": ref_us,
             "gflops": flops / ref_us * 1e-3}]

    best = None
    for bm, bn, bk in candidates:
        assert bm % 8 == 0 and bn % 128 == 0 and bk % 32 == 0, (bm, bn, bk)
        us = _median_us(lambda: ensemble_apply_kernel(
            ens, x, cfg=cfg, bm=bm, bn=bn, bk=bk))
        rows.append({"impl": "kernel", "bm": bm, "bn": bn, "bk": bk,
                     "us": us, "gflops": flops / us * 1e-3})
        if best is None or us < best["kernel_us"]:
            best = {"bm": bm, "bn": bn, "bk": bk, "kernel_us": us}

    record = dict(best, ref_us=ref_us,
                  use_kernel=best["kernel_us"] < ref_us,
                  backend=jax.default_backend(),
                  interpret=jax.default_backend() == "cpu")
    return record, rows


def sweep(problems: Sequence[Tuple[int, int, int, int]], *,
          candidates: Sequence[Tuple[int, int, int]] = DEFAULT_CANDIDATES,
          write: bool = False) -> Dict[str, dict]:
    """Autotune each (C, M, N, K) problem; with `write`, merge the winners
    into the committed `tuning.json` (other backends' entries are kept)."""
    table = dict(load_table())
    out: Dict[str, dict] = {}
    for C, M, N, K in problems:
        record, _ = autotune_problem(C, M, N, K, candidates=candidates)
        out[problem_key(C, M, N, K)] = record
    if write:
        table.update(out)
        TUNING_JSON.write_text(json.dumps(table, indent=1, sort_keys=True))
        load_table.cache_clear()
    return out


def detector_problems(det_cfg, batch: int, chips: int
                      ) -> List[Tuple[int, int, int, int]]:
    """The distinct (C, M, N, K) kernel problems of one detector config:
    every group crossbar of layer s{s}b{b} shares N = group columns and
    K = bias_rows + 9*group rows; M = batch * H_s * W_s shrinks with the
    stage's pooling."""
    probs = set()
    H = det_cfg.img_hw[0] // 2
    W = det_cfg.img_hw[1] // 2
    K = det_cfg.bias_rows + 9 * det_cfg.group
    for s, nb in enumerate(det_cfg.blocks_per_stage):
        for _ in range(nb):
            probs.add((chips, batch * H * W, det_cfg.group, K))
        H, W = H // 2, W // 2
    return sorted(probs)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="(bm, bn, bk) block-shape sweep for irc_mvm_chips")
    ap.add_argument("--chips", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--network", default="detector", choices=["detector"])
    ap.add_argument("--write", action="store_true",
                    help="merge winners into the committed tuning.json")
    args = ap.parse_args()

    from repro.configs import yolo_irc
    problems = detector_problems(yolo_irc.smoke("ternary"), args.batch,
                                 args.chips)
    print(f"# backend={jax.default_backend()} problems={problems}")
    results = sweep(problems, write=args.write)
    for key, rec in results.items():
        print(f"{key}: bm={rec['bm']} bn={rec['bn']} bk={rec['bk']} "
              f"kernel={rec['kernel_us']:.0f}us ref={rec['ref_us']:.0f}us "
              f"use_kernel={rec['use_kernel']}")
    if args.write:
        print(f"# wrote {TUNING_JSON}")


if __name__ == "__main__":
    main()
