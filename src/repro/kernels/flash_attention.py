"""Pallas TPU kernel: causal flash attention (online softmax).

Why it exists here: the 32k-context prefill cells are MEMORY-bound on
materialized [.., Sq, Sk] score/prob tensors (measured 17 GB per layer per
device on chameleon-34b prefill_32k even with the KV sequence sharded
16-way).  Flash attention keeps the score block in VMEM and streams KV
blocks with a running (max, denominator) — HBM traffic drops from
O(Sq*Sk) to O(Sq*hd + Sk*hd).

Grid: (batch*heads, Sq/bq, Sk/bk), KV walk innermost with VMEM scratch for
the accumulator and the online-softmax stats.  Causality skips fully-masked
KV blocks via pl.when.  Validated against ref.py's oracle in interpret
mode; the multi-pod dry-run keeps the XLA attention (Mosaic kernels cannot
compile on the CPU dry-run backend) — §Perf carries the analytic traffic
correction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s,
                  *, scale: float, bq: int, bk: int, nk: int, causal: bool):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    def body():
        q = q_ref[0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_s[...]                           # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    if causal:
        # skip KV blocks strictly in the future of this whole q block
        pl.when(kb * bk <= qb * bq + bq - 1)(body)
    else:
        body()

    @pl.when(kb == nk - 1)
    def _finish():
        l = jnp.maximum(l_s[...], 1e-20)
        o_ref[0] = (acc[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, causal: bool = True, bq: int = 512,
                           bk: int = 512, interpret: bool = False
                           ) -> jax.Array:
    """q [H, Sq, hd], k/v [H, Sk, hd] -> [H, Sq, hd].
    (vmap over batch; H = flattened heads.)  Sq % bq == Sk % bk == 0."""
    H, Sq, hd = q.shape
    Sk = k.shape[1]
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nk = Sk // bk
    scale = hd ** -0.5
    kernel = functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                               nk=nk, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(H, Sq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),      # output accumulator
            pltpu.VMEM((bq, 1), jnp.float32),       # running max
            pltpu.VMEM((bq, 1), jnp.float32),       # running denominator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
