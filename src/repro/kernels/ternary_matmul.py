"""Pallas TPU kernel: dense ternary matmul (the ideal digital fast path).

Ternary weights are stored as int8 {-1,0,+1} (4x smaller than f32 in HBM —
the layer is memory-bound at inference batch sizes) and upcast to the MXU
input type inside VMEM.  Classic three-loop tiled matmul with an f32 VMEM
accumulator; the R walk is the innermost grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _ternary_matmul_kernel(x_ref, w_ref, out_ref, acc, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc[...] += jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        out_ref[...] = acc[...]


def ternary_matmul_pallas(x: jax.Array, w_t: jax.Array,
                          *, bm: int = 128, bn: int = 128, bk: int = 512,
                          interpret: bool = False) -> jax.Array:
    """x [B,K] float, w_t [K,N] int8 {-1,0,1} -> f32 [B,N].
    Tile-aligned shapes required; see ops.ternary_matmul for padding."""
    B, K = x.shape
    N = w_t.shape[1]
    assert B % bm == 0 and K % bk == 0 and N % bn == 0, (B, K, N, bm, bk, bn)
    nk = K // bk
    kernel = functools.partial(_ternary_matmul_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_t)
