"""repro.kernels — Pallas TPU kernels for the IRC hot spots.

  irc_mvm         fused single-shot crossbar MVM + nonideal epilogue
  irc_mvm_chips   chip-batched grid variant: one launch per chip ensemble
  ternary_matmul  dense int8-ternary matmul (ideal digital path)

Each kernel ships with a pure-jnp oracle in ref.py; on CPU the kernels run
in interpret mode (the dispatch lives in ops.py).
"""
from repro.kernels.ref import (IrcEpilogueParams, irc_mvm_ref,
                               irc_mvm_chips_ref, ternary_matmul_ref,
                               nl_ratio, flash_attention_ref)
from repro.kernels.ops import (irc_mvm, irc_mvm_chips, ternary_matmul,
                               irc_mvm_from_mapped, flash_attention)
