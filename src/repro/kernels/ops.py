"""Public jit'd entry points for the Pallas kernels (padding + dispatch).

On CPU (this container) the kernels execute in interpret mode; on TPU they
compile to Mosaic.  Shapes are padded to tile multiples here so callers can
pass arbitrary layer shapes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

# re-exported: ops is the backend-dispatch facade over the ref kernels
from repro.kernels.ref import (IrcEpilogueParams, irc_mvm_ref,  # noqa: F401
                               ternary_matmul_ref)
from repro.kernels.irc_mvm import irc_mvm_pallas, irc_mvm_chips_pallas
from repro.kernels.ternary_matmul import ternary_matmul_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(a: jax.Array, axis: int, mult: int) -> jax.Array:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("params", "bm", "bn", "bk",
                                             "interpret"))
def irc_mvm(x: jax.Array, ep: jax.Array, en: jax.Array,
            gp: jax.Array, gn: jax.Array,
            eps_sa: jax.Array, rnd_bits: jax.Array,
            params: IrcEpilogueParams,
            bm: int = 8, bn: int = 128, bk: int = 256,
            interpret: Optional[bool] = None) -> jax.Array:
    """Fused single-shot IRC crossbar MVM (see irc_mvm.py docstring).

    Accepts arbitrary (B, R, N); pads to tile multiples.  Padded rows are
    zero-conductance (contribute no current, no counts), padded batch/cols
    are sliced off.
    """
    B, R = x.shape
    N = ep.shape[1]
    interp = _on_cpu() if interpret is None else interpret
    x = _pad_to(_pad_to(x, 0, bm), 1, bk)
    pad_plane = lambda p: _pad_to(_pad_to(p, 0, bk), 1, bn)
    ep, en, gp, gn = map(pad_plane, (ep, en, gp, gn))
    pad_bn = lambda p: _pad_to(_pad_to(p, 0, bm), 1, bn)
    eps_sa, rnd_bits = map(pad_bn, (eps_sa, rnd_bits))
    out = irc_mvm_pallas(x, ep, en, gp, gn, eps_sa, rnd_bits, params,
                         bm=bm, bn=bn, bk=bk, interpret=interp)
    return out[:B, :N]


@functools.partial(jax.jit, static_argnames=("params", "bm", "bn", "bk",
                                             "interpret"))
def irc_mvm_chips(x: jax.Array, ep: jax.Array, en: jax.Array,
                  gp: jax.Array, gn: jax.Array,
                  eps_sa: jax.Array, rnd_bits: jax.Array,
                  params: IrcEpilogueParams,
                  bm: int = 8, bn: int = 128, bk: int = 256,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Chip-batched fused IRC MVM: x [B,R] shared (or [C,B,R] per-chip
    word-line stream), effective planes [C,R,N], placement planes [C,R,N] or
    shared [R,N], periphery noise [C,B,N] -> [C,B,N] in ONE kernel launch
    (the `repro.mc` hot path).

    Accepts arbitrary (C, B, R, N); pads B/R/N to tile multiples (padded rows
    are zero-conductance, padded batch/cols are sliced off; the chips axis
    needs no padding — it maps 1:1 onto the outermost grid dimension).
    """
    B, R = x.shape[-2:]
    C, _, N = ep.shape
    interp = _on_cpu() if interpret is None else interpret
    x = _pad_to(_pad_to(x, x.ndim - 2, bm), x.ndim - 1, bk)
    pad_plane = lambda p: _pad_to(_pad_to(p, p.ndim - 2, bk), p.ndim - 1, bn)
    ep, en, gp, gn = map(pad_plane, (ep, en, gp, gn))
    pad_bn = lambda p: _pad_to(_pad_to(p, 1, bm), 2, bn)
    eps_sa, rnd_bits = map(pad_bn, (eps_sa, rnd_bits))
    out = irc_mvm_chips_pallas(x, ep, en, gp, gn, eps_sa, rnd_bits, params,
                               bm=bm, bn=bn, bk=bk, interpret=interp)
    return out[:, :B, :N]


def irc_mvm_from_mapped(key: jax.Array, x_bits: jax.Array, mapped,
                        cfg, spec, *, sa_extra_units: float = 0.0,
                        output: str = "binary",
                        bm: int = 8, bn: int = 128, bk: int = 256) -> jax.Array:
    """Kernel-backed equivalent of `repro.core.crossbar.crossbar_forward`
    (single-shot mode): samples the variation masks / SA noise with the SAME
    key discipline, pre-applies them to the conductance planes, and calls the
    fused kernel.  Bit-exact agreement is covered by tests/test_kernels.py.
    """
    from repro.core.mapping import extend_inputs
    from repro.core.crossbar import sample_chip_planes
    gp, gn = mapped.g_pos, mapped.g_neg
    ep, en, k_sa = sample_chip_planes(key, gp, gn, mapped.scheme, cfg, spec)
    k_off, k_rng = jax.random.split(k_sa)
    x_ext = extend_inputs(x_bits.astype(jnp.float32), mapped)
    B, N = x_ext.shape[0], gp.shape[1]
    eps_sa = jax.random.normal(k_off, (B, N), jnp.float32)
    rnd = jax.random.bernoulli(k_rng, 0.5, (B, N)).astype(jnp.float32)
    params = IrcEpilogueParams.from_macro(
        spec, sa_extra=sa_extra_units, output=output,
        apply_nonlinearity=cfg.nonlinearity, apply_ir=cfg.ir_drop,
        apply_sa=cfg.sa_variation, apply_range=cfg.sensing_range)
    return irc_mvm(x_ext, ep, en, gp, gn, eps_sa, rnd, params,
                   bm=bm, bn=bn, bk=bk)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = 512, bk: int = 512,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Causal flash attention: q [H,Sq,hd], k/v [H,Sk,hd] -> [H,Sq,hd].
    Sequences are zero-padded to block multiples; with causal masking the
    padded KV tail can never attend into real queries.  vmap over batch."""
    assert causal, "public wrapper supports the causal case"
    H, Sq, hd = q.shape
    Sk = k.shape[1]
    interp = _on_cpu() if interpret is None else interpret
    bq_ = min(bq, Sq) if Sq % min(bq, Sq) == 0 else Sq
    bk_ = min(bk, Sk) if Sk % min(bk, Sk) == 0 else Sk
    qp = _pad_to(q, 1, bq_)
    kp = _pad_to(k, 1, bk_)
    vp = _pad_to(v, 1, bk_)
    out = flash_attention_pallas(qp, kp, vp, causal=True, bq=bq_, bk=bk_,
                                 interpret=interp)
    return out[:, :Sq]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def ternary_matmul(x: jax.Array, w_t: jax.Array,
                   bm: int = 128, bn: int = 128, bk: int = 512,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Dense ternary matmul with int8-packed weights."""
    B, K = x.shape
    N = w_t.shape[1]
    interp = _on_cpu() if interpret is None else interpret
    x = _pad_to(_pad_to(x, 0, bm), 1, bk)
    w_t = _pad_to(_pad_to(w_t, 0, bk), 1, bn)
    out = ternary_matmul_pallas(x, w_t, bm=bm, bn=bn, bk=bk, interpret=interp)
    return out[:B, :N]
