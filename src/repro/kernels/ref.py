"""Pure-jnp oracles for the Pallas kernels.

`irc_mvm_ref` mirrors `repro.kernels.irc_mvm` exactly: the proposed design's
single-shot crossbar MVM with the fused nonideal epilogue.  Conductance
planes arrive with device variation and HRS leak PRE-APPLIED (programming a
chip is static; masks are sampled once per simulated die, outside the MVM),
and the stochastic periphery terms arrive as externally sampled noise so the
kernel itself is deterministic and exactly testable.

`ternary_matmul_ref` is the ideal digital path: {0,1} activations x int8
ternary weights.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class IrcEpilogueParams:
    """Static epilogue constants (from MacroSpec, in LRS units)."""
    ir_alpha: float = 1.5e-5
    ir_block: int = 32
    sense_low: float = 35.0
    sense_high: float = 300.0
    sa_c0: float = 2.0
    sa_c1: float = 0.012
    sa_c2: float = 2.2e-5
    sa_extra: float = 0.0
    apply_nonlinearity: bool = True
    apply_ir: bool = True
    apply_sa: bool = True
    apply_range: bool = True
    output: str = "binary"            # "binary" | "diff"

    @classmethod
    def from_macro(cls, spec, **overrides) -> "IrcEpilogueParams":
        kw = dict(ir_alpha=spec.ir_alpha, ir_block=spec.ir_block,
                  sense_low=spec.sense_low_units, sense_high=spec.sense_high_units,
                  sa_c0=spec.sa_c0, sa_c1=spec.sa_c1, sa_c2=spec.sa_c2)
        kw.update(overrides)
        return cls(**kw)


# exact published piecewise quartic (Sec. III-C), clamped to fit domain
_NL_LO = (1.0286e-8, -3.79e-6, 5.3e-4, -3.92e-2, 2.5)
_NL_HI = (1.8063e-11, -3.204e-8, 2.2495e-5, -8.057e-3, 1.707)


def nl_ratio(p: jax.Array) -> jax.Array:
    p_raw = p.astype(jnp.float32)
    p = jnp.clip(p_raw, 0.0, 320.0)
    def horner(c):
        acc = jnp.full_like(p, c[0])
        for x in c[1:]:
            acc = acc * p + x
        return acc
    ratio = jnp.where(p <= 140.0, horner(_NL_LO), horner(_NL_HI))
    return jnp.where(p_raw < 0.5, 1.0, ratio)


def _line_current(x: jax.Array, eplane: jax.Array, ep_: IrcEpilogueParams
                  ) -> jax.Array:
    """Accumulate one plane with the IR-drop block model.
    x [B,R], eplane [R,N] -> [B,N].  R is padded up to a multiple of the IR
    block size; appended zero rows sit at the far end of the bit-line and
    carry no current, so the drop factors of real blocks are unchanged."""
    pad = (-x.shape[1]) % ep_.ir_block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        eplane = jnp.pad(eplane, ((0, pad), (0, 0)))
    B, R = x.shape
    N = eplane.shape[1]
    nb = R // ep_.ir_block
    xb = x.reshape(B, nb, ep_.ir_block)
    pb = eplane.reshape(nb, ep_.ir_block, N)
    blocks = jnp.einsum("bik,ikn->bin", xb, pb)          # [B, nb, N]
    if ep_.apply_ir:
        bl = jnp.moveaxis(blocks, 1, 2)                   # [B, N, nb]
        suffix = jnp.cumsum(bl[..., ::-1], axis=-1)[..., ::-1]
        cum = jnp.cumsum(suffix, axis=-1) - suffix[..., 0:1]
        factors = jnp.clip(1.0 - ep_.ir_alpha * cum, 0.0, 1.0)
        blocks = blocks * jnp.moveaxis(factors, 2, 1)
    return jnp.sum(blocks, axis=1)


def irc_mvm_ref(x: jax.Array, ep: jax.Array, en: jax.Array,
                gp: jax.Array, gn: jax.Array,
                eps_sa: jax.Array, rnd_bits: jax.Array,
                params: IrcEpilogueParams) -> jax.Array:
    """Oracle for the fused IRC MVM kernel.

    x        [B, R]  word-line bits {0,1} (bias rows already prefixed)
    ep, en   [R, N]  effective conductances (variation/leak pre-applied)
    gp, gn   [R, N]  binary LRS placement planes (for activated-LRS counts)
    eps_sa   [B, N]  ~N(0,1) SA offset noise
    rnd_bits [B, N]  {0,1} fallback bits for unresolvable comparisons
    """
    x = x.astype(jnp.float32)
    i_pos = _line_current(x, ep.astype(jnp.float32), params)
    i_neg = _line_current(x, en.astype(jnp.float32), params)
    p_pos = x @ gp.astype(jnp.float32)
    p_neg = x @ gn.astype(jnp.float32)
    if params.apply_nonlinearity:
        i_pos = i_pos * nl_ratio(p_pos)
        i_neg = i_neg * nl_ratio(p_neg)
    diff = i_pos - i_neg
    if params.output == "diff":
        return diff
    p_pair = p_pos + p_neg
    if params.apply_sa:
        sigma = 0.5 * (params.sa_c0 + params.sa_c1 * p_pair
                       + params.sa_c2 * p_pair * p_pair + params.sa_extra)
        diff = diff + sigma * eps_sa
    out = (diff > 0).astype(jnp.float32)
    if params.apply_range:
        fail = jnp.logical_or(jnp.minimum(i_pos, i_neg) < params.sense_low,
                              jnp.maximum(i_pos, i_neg) > params.sense_high)
        out = jnp.where(fail, rnd_bits, out)
    return out


def irc_mvm_chips_ref(x: jax.Array, ep: jax.Array, en: jax.Array,
                      gp: jax.Array, gn: jax.Array,
                      eps_sa: jax.Array, rnd_bits: jax.Array,
                      params: IrcEpilogueParams) -> jax.Array:
    """Oracle for the chip-batched kernel: vmap of `irc_mvm_ref` over the
    leading chips axis of the planes / periphery noise.

    x [B, R] (shared word lines) or [C, B, R] (per-chip word-line stream);
    ep/en [C, R, N]; gp/gn [C, R, N] or shared [R, N];
    eps/rnd [C, B, N] -> [C, B, N]."""
    count_axis = None if gp.ndim == 2 else 0
    x_axis = None if x.ndim == 2 else 0
    return jax.vmap(
        lambda x_c, ep_c, en_c, gp_c, gn_c, eps_c, rnd_c: irc_mvm_ref(
            x_c, ep_c, en_c, gp_c, gn_c, eps_c, rnd_c, params),
        in_axes=(x_axis, 0, 0, count_axis, count_axis, 0, 0)
    )(x, ep, en, gp, gn, eps_sa, rnd_bits)


def ternary_matmul_ref(x: jax.Array, w_t: jax.Array) -> jax.Array:
    """Ideal digital ternary matmul oracle: x [B,K] (any float), w_t [K,N]
    int8 in {-1,0,1} -> f32 [B,N]."""
    return x.astype(jnp.float32) @ w_t.astype(jnp.float32)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Oracle for the flash kernel: plain softmax attention.
    q [H,Sq,hd], k/v [H,Sk,hd] -> [H,Sq,hd]."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Sq, Sk = s.shape[-2:]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
