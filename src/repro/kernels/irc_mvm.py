"""Pallas TPU kernel: fused single-shot IRC crossbar MVM + nonideal epilogue.

This is the compute hot spot of the structural simulation (paper Secs. III-IV):
for each (batch, output-channel) tile it computes, entirely in VMEM,

  1. per-32-row-sub-block partial currents for both conductance planes
     (the IR-drop block model needs them individually) — MXU batched dots;
  2. activated-LRS counts per plane — two MXU dots;
  3. the fused epilogue: IR-drop suffix-cumsum weighting, the paper's
     piecewise-quartic accumulation nonlinearity, differential SA comparison
     with offset noise and limited-sensing-range fallback — all VPU.

A naive jnp composition round-trips [B, n_blocks, N] block currents and the
count/current tensors through HBM ~10 times; the kernel keeps everything in
VMEM scratch across the R-dimension grid walk and writes only the [B, N]
binary output.

Tiling: grid = (B/bm, N/bn, R/bk) with the R walk innermost ("arbitrary"
semantics, accumulation in scratch).  Defaults bm=8 (sublane), bn=128
(lane), bk=256 (8 IR blocks / MXU-friendly contraction) — sweepable; VMEM
footprint at defaults is <1 MB, and all matmul dims are multiples of
(8, 128) for MXU alignment.

Stochastic terms (SA offset noise, unresolvable-comparison fallback bits)
are pre-sampled inputs, so the kernel is deterministic and exactly testable
against `ref.irc_mvm_ref` (interpret=True on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

from repro.kernels.ref import IrcEpilogueParams, _NL_LO, _NL_HI


def _nl_ratio_inline(p: jax.Array) -> jax.Array:
    p_raw = p
    p = jnp.clip(p_raw, 0.0, 320.0)
    def horner(c):
        acc = jnp.full_like(p, c[0])
        for x in c[1:]:
            acc = acc * p + x
        return acc
    ratio = jnp.where(p <= 140.0, horner(_NL_LO), horner(_NL_HI))
    return jnp.where(p_raw < 0.5, 1.0, ratio)


def _accum_step(x, ep, en, gp, gn, blocks_p, blocks_n, p_pos, p_neg,
                k, nbk, blk):
    """One R-walk step: full-tile count dots + per-IR-block partial-current
    dots, accumulated into the VMEM scratch (shared by both kernels)."""
    bm = x.shape[0]
    bn = ep.shape[1]

    # activated-LRS counts: full-tile MXU dots
    dot = functools.partial(jax.lax.dot_general,
                            dimension_numbers=(((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    p_pos[...] += dot(x, gp)
    p_neg[...] += dot(x, gn)

    # per-IR-block partial currents: batched MXU dots over the 32-row blocks
    xb = x.reshape(bm, nbk, blk).transpose(1, 0, 2)       # (nbk, bm, 32)
    epb = ep.reshape(nbk, blk, bn)
    enb = en.reshape(nbk, blk, bn)
    bdot = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    blocks_p[pl.ds(k * nbk, nbk)] = bdot(xb, epb)         # (nbk, bm, bn)
    blocks_n[pl.ds(k * nbk, nbk)] = bdot(xb, enb)


def _epilogue_tile(blocks_p, blocks_n, pp, pn, eps, rnd,
                   params: IrcEpilogueParams) -> jax.Array:
    """Fused VPU epilogue on one (bm, bn) tile: IR-drop weighting,
    accumulation nonlinearity, SA comparison + sensing-range fallback."""
    def line(blocks):                                     # (NBT, bm, bn)
        if params.apply_ir:
            rev = blocks[::-1]
            suffix = jnp.cumsum(rev, axis=0)[::-1]
            cum = jnp.cumsum(suffix, axis=0) - suffix[0:1]
            factors = jnp.clip(1.0 - params.ir_alpha * cum, 0.0, 1.0)
            return jnp.sum(blocks * factors, axis=0)
        return jnp.sum(blocks, axis=0)

    i_pos = line(blocks_p)
    i_neg = line(blocks_n)
    if params.apply_nonlinearity:
        i_pos = i_pos * _nl_ratio_inline(pp)
        i_neg = i_neg * _nl_ratio_inline(pn)
    diff = i_pos - i_neg
    if params.output == "diff":
        return diff
    if params.apply_sa:
        p_pair = pp + pn
        sigma = 0.5 * (params.sa_c0 + params.sa_c1 * p_pair
                       + params.sa_c2 * p_pair * p_pair + params.sa_extra)
        diff = diff + sigma * eps
    out = (diff > 0).astype(jnp.float32)
    if params.apply_range:
        fail = jnp.logical_or(
            jnp.minimum(i_pos, i_neg) < params.sense_low,
            jnp.maximum(i_pos, i_neg) > params.sense_high)
        out = jnp.where(fail, rnd, out)
    return out


def _irc_mvm_kernel(x_ref, ep_ref, en_ref, gp_ref, gn_ref, eps_ref, rnd_ref,
                    out_ref, blocks_p, blocks_n, p_pos, p_neg,
                    *, params: IrcEpilogueParams, nk: int, bk: int):
    k = pl.program_id(2)
    blk = params.ir_block
    nbk = bk // blk                      # IR blocks contributed this step

    @pl.when(k == 0)
    def _init():
        blocks_p[...] = jnp.zeros_like(blocks_p)
        blocks_n[...] = jnp.zeros_like(blocks_n)
        p_pos[...] = jnp.zeros_like(p_pos)
        p_neg[...] = jnp.zeros_like(p_neg)

    _accum_step(x_ref[...].astype(jnp.float32),
                ep_ref[...].astype(jnp.float32),
                en_ref[...].astype(jnp.float32),
                gp_ref[...].astype(jnp.float32),
                gn_ref[...].astype(jnp.float32),
                blocks_p, blocks_n, p_pos, p_neg, k, nbk, blk)

    @pl.when(k == nk - 1)
    def _epilogue():
        out_ref[...] = _epilogue_tile(blocks_p[...], blocks_n[...],
                                      p_pos[...], p_neg[...],
                                      eps_ref[...], rnd_ref[...], params)


def _irc_mvm_chips_kernel(x_ref, ep_ref, en_ref, gp_ref, gn_ref, eps_ref,
                          rnd_ref, out_ref, blocks_p, blocks_n, p_pos, p_neg,
                          *, params: IrcEpilogueParams, nk: int, bk: int,
                          shared_counts: bool, per_chip_x: bool):
    """Chip-batched variant: grid (chips, B/bm, N/bn, R/bk); the plane /
    periphery refs carry a leading length-1 chip block.  The word-line tile
    is SHARED by every chip by default (one ensemble evaluates one input
    batch), so the extra grid dimension reuses the x block across the chip
    walk; with `per_chip_x` the word-line tile carries its own length-1 chip
    block instead — how network-level MC feeds chip-diverged activations
    from one IRC layer into the next.  With `shared_counts` the LRS
    placement planes are chip-independent too and arrive as plain 2-D tiles
    (one HBM copy serves every chip)."""
    k = pl.program_id(3)
    blk = params.ir_block
    nbk = bk // blk

    @pl.when(k == 0)
    def _init():
        blocks_p[...] = jnp.zeros_like(blocks_p)
        blocks_n[...] = jnp.zeros_like(blocks_n)
        p_pos[...] = jnp.zeros_like(p_pos)
        p_neg[...] = jnp.zeros_like(p_neg)

    gp = gp_ref[...] if shared_counts else gp_ref[0]
    gn = gn_ref[...] if shared_counts else gn_ref[0]
    x = x_ref[0] if per_chip_x else x_ref[...]
    _accum_step(x.astype(jnp.float32),
                ep_ref[0].astype(jnp.float32),
                en_ref[0].astype(jnp.float32),
                gp.astype(jnp.float32),
                gn.astype(jnp.float32),
                blocks_p, blocks_n, p_pos, p_neg, k, nbk, blk)

    @pl.when(k == nk - 1)
    def _epilogue():
        out_ref[0] = _epilogue_tile(blocks_p[...], blocks_n[...],
                                    p_pos[...], p_neg[...],
                                    eps_ref[0], rnd_ref[0], params)


def irc_mvm_pallas(x: jax.Array, ep: jax.Array, en: jax.Array,
                   gp: jax.Array, gn: jax.Array,
                   eps_sa: jax.Array, rnd_bits: jax.Array,
                   params: IrcEpilogueParams,
                   *, bm: int = 8, bn: int = 128, bk: int = 256,
                   interpret: bool = False) -> jax.Array:
    """Raw pallas_call wrapper; shapes must already be tile-aligned
    (B % bm == N % bn == R % bk == 0, bk % ir_block == 0).  Use
    `repro.kernels.ops.irc_mvm` for the padded/jit public entry point."""
    B, R = x.shape
    N = ep.shape[1]
    assert R % bk == 0 and bk % params.ir_block == 0, (R, bk, params.ir_block)
    assert B % bm == 0 and N % bn == 0, (B, bm, N, bn)
    nk = R // bk
    nbt = R // params.ir_block

    grid = (B // bm, N // bn, nk)
    kernel = functools.partial(_irc_mvm_kernel, params=params, nk=nk, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # ep
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # en
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # gp
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # gn
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),   # eps_sa
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),   # rnd_bits
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((nbt, bm, bn), jnp.float32),   # blocks_p
            pltpu.VMEM((nbt, bm, bn), jnp.float32),   # blocks_n
            pltpu.VMEM((bm, bn), jnp.float32),        # p_pos
            pltpu.VMEM((bm, bn), jnp.float32),        # p_neg
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, ep, en, gp, gn, eps_sa, rnd_bits)


def irc_mvm_chips_pallas(x: jax.Array, ep: jax.Array, en: jax.Array,
                         gp: jax.Array, gn: jax.Array,
                         eps_sa: jax.Array, rnd_bits: jax.Array,
                         params: IrcEpilogueParams,
                         *, bm: int = 8, bn: int = 128, bk: int = 256,
                         interpret: bool = False) -> jax.Array:
    """Chip-batched raw wrapper: one launch services a whole chip ensemble.

    x [B, R] is shared — or [C, B, R] with a per-chip word-line stream
    (chip-diverged activations downstream of the first IRC layer); ep/en
    [C, R, N] and eps/rnd [C, B, N] carry the chips axis; gp/gn are either
    [C, R, N] (per-chip placement, e.g. after per-die bias calibration) or
    [R, N] (shared placement — one HBM copy serves every chip); output is
    [C, B, N].  The chips grid dimension is outermost and fully parallel —
    on TPU the C x (B/bm) x (N/bn) tiles schedule like one big MVM instead
    of C kernel launches.  Shapes must be tile-aligned (use
    `repro.kernels.ops.irc_mvm_chips` for the padded entry point).
    """
    per_chip_x = x.ndim == 3
    B, R = x.shape[-2:]
    C, _, N = ep.shape
    shared_counts = gp.ndim == 2
    assert R % bk == 0 and bk % params.ir_block == 0, (R, bk, params.ir_block)
    assert B % bm == 0 and N % bn == 0, (B, bm, N, bn)
    nk = R // bk
    nbt = R // params.ir_block

    grid = (C, B // bm, N // bn, nk)
    kernel = functools.partial(_irc_mvm_chips_kernel, params=params,
                               nk=nk, bk=bk, shared_counts=shared_counts,
                               per_chip_x=per_chip_x)
    plane = pl.BlockSpec((1, bk, bn), lambda c, i, j, k: (c, k, j))
    count = (pl.BlockSpec((bk, bn), lambda c, i, j, k: (k, j))
             if shared_counts else plane)
    peri = pl.BlockSpec((1, bm, bn), lambda c, i, j, k: (c, i, j))
    x_spec = (pl.BlockSpec((1, bm, bk), lambda c, i, j, k: (c, i, k))
              if per_chip_x
              else pl.BlockSpec((bm, bk), lambda c, i, j, k: (i, k)))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            x_spec,                                              # x
            plane, plane, count, count,                          # ep en gp gn
            peri, peri,                                          # eps_sa, rnd
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda c, i, j, k: (c, i, j)),
        out_shape=jax.ShapeDtypeStruct((C, B, N), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((nbt, bm, bn), jnp.float32),   # blocks_p
            pltpu.VMEM((nbt, bm, bn), jnp.float32),   # blocks_n
            pltpu.VMEM((bm, bn), jnp.float32),        # p_pos
            pltpu.VMEM((bm, bn), jnp.float32),        # p_neg
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, ep, en, gp, gn, eps_sa, rnd_bits)
