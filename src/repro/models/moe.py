"""Token-choice top-k MoE with capacity-based scatter dispatch (EP-friendly).

Dispatch avoids the O(T * E * C) one-hot einsum: slot positions come from a
per-expert cumulative count, tokens scatter into [E, C, D] buckets, experts
run as one batched SwiGLU over the expert dimension (shardable over the
'model'/EP mesh axis -> XLA inserts the all-to-all), and outputs gather back
with router weights.  Tokens beyond capacity are dropped (standard
capacity-factor semantics); the router adds a load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.lm_config import LMConfig


def moe_specs(cfg: LMConfig) -> Dict[str, ParamSpec]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    pd = cfg.pdtype
    return {
        "router": ParamSpec((d, e), ("embed", "experts"), dtype=jnp.float32),
        "w_gate": ParamSpec((e, d, ff), ("experts", "embed", "mlp"), dtype=pd),
        "w_up": ParamSpec((e, d, ff), ("experts", "embed", "mlp"), dtype=pd),
        "w_down": ParamSpec((e, ff, d), ("experts", "mlp", "embed"), dtype=pd),
    }


def _capacity(cfg: LMConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(8, -(-c // 8) * 8)    # pad to 8 for TPU-friendly shapes


def moe_block(params, x: jax.Array, cfg: LMConfig, constrain=None,
              dispatch_groups: int = 1
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x [B,S,D] -> (out [B,S,D], {"aux_loss": scalar}).

    Dispatch is GROUP-LOCAL: tokens split into `dispatch_groups` (= the DP
    shard count), each group scattering into its own capacity buckets
    [G, E, C_local, D] — G shards over the data axes, E over 'model' (EP).
    A single global-capacity dispatch would make every data shard compute
    capacity slots for the WHOLE global batch (measured 45x expert-FLOP
    inflation on qwen3-moe).  `constrain(x, axes)` pins the EP sharding;
    the scatter across (G, E) is the all-to-all.
    """
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    G = dispatch_groups if T % dispatch_groups == 0 else 1
    Tl = T // G
    C = _capacity(cfg, Tl)
    xt = x.reshape(G, Tl, D)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["router"])                         # [G,Tl,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # [G,Tl,K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style, global means)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # slot assignment per group: position within each expert's local queue
    sel = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)          # [G,Tl,K,E]
    flat_sel = sel.reshape(G, Tl * K, E)
    pos_in_expert = jnp.cumsum(flat_sel, axis=1) - flat_sel
    slot = jnp.sum(pos_in_expert * flat_sel, axis=-1)             # [G,Tl*K]
    eid = expert_idx.reshape(G, Tl * K)
    keep = slot < C
    slot = jnp.where(keep, slot, C)                               # C = trash

    # scatter tokens into [G, E, C+1, D] buckets (vmapped over groups)
    tok_ids = jnp.repeat(jnp.arange(Tl), K)

    def scatter_group(xg, eidg, slotg):
        b = jnp.zeros((E, C + 1, D), x.dtype)
        return b.at[eidg, slotg].set(xg[tok_ids], mode="drop")

    buckets = jax.vmap(scatter_group)(xt, eid, slot)              # [G,E,C+1,D]

    h = buckets[:, :, :C, :]
    if constrain is not None:
        h = constrain(h, ("act_batch", "experts", None, "act_embed"))
    dt = x.dtype
    gate = jnp.einsum("gecd,edf->gecf", h, params["w_gate"].astype(dt))
    up = jnp.einsum("gecd,edf->gecf", h, params["w_up"].astype(dt))
    y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * up,
                   params["w_down"].astype(dt))                   # [G,E,C,D]
    if constrain is not None:
        y = constrain(y, ("act_batch", "experts", None, "act_embed"))

    # gather back with router weights (vmapped over groups)
    def combine_group(yg, eidg, slotg, wg):
        y_pad = jnp.concatenate([yg, jnp.zeros((E, 1, D), yg.dtype)], axis=1)
        y_tok = y_pad[eidg, slotg]                                # [Tl*K,D]
        return jnp.zeros((Tl, D), dt).at[tok_ids].add(y_tok * wg[:, None])

    w = (gate_vals.reshape(G, Tl * K) * keep).astype(dt)
    out = jax.vmap(combine_group)(y, eid, slot, w)                # [G,Tl,D]
    return out.reshape(B, S, D), {"aux_loss": aux}
