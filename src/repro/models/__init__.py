"""repro.models — pure-JAX model zoo substrate.

Decoder-only LM composition covering the 10 assigned architectures (dense /
GQA / sliding-window / softcap / MoE / hybrid-SSM / RWKV6) plus the paper's
own IRC object detector.  Params are plain nested dicts built from ParamSpec
tables (single source of truth for shapes + logical sharding axes).
"""
from repro.models.common import ParamSpec, materialize, logical_axes_tree
from repro.models.lm_config import LMConfig
from repro.models.transformer import LM
from repro.models.detector import IRCDetector, DetectorConfig
