"""RWKV6 ("Finch") block: time-mix with DATA-DEPENDENT decay + channel-mix.

The WKV recurrence keeps a per-head [hd, hd] state — O(1) in sequence
length, so rwkv6 runs the `long_500k` cell.  Training/prefill scans over
time with `lax.scan` (compiles O(1) in T); decode is a single state update.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.lm_config import LMConfig


def _heads(cfg: LMConfig) -> Tuple[int, int]:
    hd = cfg.head_dim
    return cfg.d_model // hd, hd


def rwkv_specs(cfg: LMConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    ff = cfg.d_ff
    pd = cfg.pdtype
    lora = max(32, d // 16)
    return {
        "time": {
            # token-shift lerp coefficients for r/k/v/w/g
            "mu": ParamSpec((5, d), (None, "embed"), init="zeros", dtype=pd),
            "w_r": ParamSpec((d, d), ("embed", "heads_qkv"), dtype=pd),
            "w_k": ParamSpec((d, d), ("embed", "heads_qkv"), dtype=pd),
            "w_v": ParamSpec((d, d), ("embed", "heads_qkv"), dtype=pd),
            "w_g": ParamSpec((d, d), ("embed", "heads_qkv"), dtype=pd),
            "w_o": ParamSpec((d, d), ("heads_qkv", "embed"), dtype=pd),
            # data-dependent decay: w_t = exp(-exp(w0 + tanh(x A) B))
            "decay_w0": ParamSpec((d,), ("embed",), init="zeros", dtype=jnp.float32),
            "decay_a": ParamSpec((d, lora), ("embed", None), dtype=pd),
            "decay_b": ParamSpec((lora, d), (None, "embed"),
                                 init="scaled", scale=0.1, dtype=pd),
            "bonus_u": ParamSpec((d,), ("embed",), init="zeros", dtype=jnp.float32),
            "ln_x": ParamSpec((d,), ("embed",), init="ones", dtype=pd),
        },
        "channel": {
            "mu": ParamSpec((2, d), (None, "embed"), init="zeros", dtype=pd),
            "w_k": ParamSpec((d, ff), ("embed", "mlp"), dtype=pd),
            "w_v": ParamSpec((ff, d), ("mlp", "embed"), dtype=pd),
            "w_r": ParamSpec((d, d), ("embed", "heads_qkv"), dtype=pd),
        },
    }


def _shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """Token shift: previous token's features (last = carry from prefix)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _time_mix_terms(tp, x, xx, cfg: LMConfig):
    """r/k/v/g/decay for a chunk.  x, xx (shifted) [B,S,D]."""
    H, hd = _heads(cfg)
    mu = tp["mu"].astype(x.dtype)
    mix = lambda i: x + (xx - x) * mu[i]
    r = mix(0) @ tp["w_r"].astype(x.dtype)
    k = mix(1) @ tp["w_k"].astype(x.dtype)
    v = mix(2) @ tp["w_v"].astype(x.dtype)
    g = jax.nn.silu(mix(4) @ tp["w_g"].astype(x.dtype))
    xw = mix(3).astype(jnp.float32)
    decay_raw = tp["decay_w0"] + jnp.tanh(
        xw @ tp["decay_a"].astype(jnp.float32)) @ tp["decay_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay_raw - 3.0))        # data-dependent decay (0,1)
    shp = x.shape[:-1] + (H, hd)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp),
            g, w.reshape(shp))


def _wkv_step(state, inputs, u):
    """state [B,H,hd,hd]; r/k/v/w [B,H,hd] for one step."""
    r, k, v, w = inputs
    kv = k[..., :, None] * v[..., None, :]                 # [B,H,hd,hd]
    out = jnp.einsum("bhk,bhkv->bhv", r, state + u[..., :, None] * kv)
    new_state = state * w[..., :, None] + kv
    return new_state, out


def time_mix(tp, x: jax.Array, cfg: LMConfig, last_x: jax.Array,
             state: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill/train over a sequence.  Returns (out, new_last_x, new_state)."""
    B, S, D = x.shape
    H, hd = _heads(cfg)
    xx = _shift(x, last_x)
    r, k, v, g, w = _time_mix_terms(tp, x, xx, cfg)
    u = tp["bonus_u"].reshape(H, hd)

    def step(s, rkvw):
        return _wkv_step(s, rkvw, u)

    rkvw = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    state, outs = jax.lax.scan(step, state, rkvw)          # outs [S,B,H,hd]
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, D).astype(x.dtype)
    # group-norm per head approximated by RMS over features
    out32 = out.astype(jnp.float32)
    out = (out32 * jax.lax.rsqrt(
        jnp.mean(jnp.square(out32), axis=-1, keepdims=True) + 1e-5)
        * tp["ln_x"].astype(jnp.float32)).astype(x.dtype)
    out = out * g
    return out @ tp["w_o"].astype(x.dtype), x[:, -1, :], state


def channel_mix(cp, x: jax.Array, last_x: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    xx = _shift(x, last_x)
    mu = cp["mu"].astype(x.dtype)
    xk = x + (xx - x) * mu[0]
    xr = x + (xx - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ cp["w_k"].astype(x.dtype)))
    r = jax.nn.sigmoid(xr @ cp["w_r"].astype(x.dtype))
    return r * (k @ cp["w_v"].astype(x.dtype)), x[:, -1, :]


def rwkv_block(params, x: jax.Array, cfg: LMConfig, state: Dict
               ) -> Tuple[jax.Array, Dict]:
    """Full RWKV6 block over a sequence chunk with carried state.
    state = {"wkv": [B,H,hd,hd] f32, "tshift": [B,D], "cshift": [B,D]}."""
    out_t, new_tshift, new_wkv = time_mix(params["time"], x, cfg,
                                          state["tshift"], state["wkv"])
    x = x + out_t
    out_c, new_cshift = channel_mix(params["channel"], x, state["cshift"])
    x = x + out_c
    return x, {"wkv": new_wkv, "tshift": new_tshift, "cshift": new_cshift}


def init_rwkv_state(cfg: LMConfig, batch: int, n_layers: int) -> Dict:
    H, hd = _heads(cfg)
    return {
        "wkv": jnp.zeros((n_layers, batch, H, hd, hd), jnp.float32),
        "tshift": jnp.zeros((n_layers, batch, cfg.d_model), cfg.adtype),
        "cshift": jnp.zeros((n_layers, batch, cfg.d_model), cfg.adtype),
    }
