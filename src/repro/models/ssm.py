"""Selective SSM (Mamba-style) branch for the hybrid (hymba) block.

Hymba runs attention heads and SSM heads in parallel within a layer; this
module is the SSM branch: in-proj -> depthwise conv -> selective scan
(data-dependent dt/B/C, diagonal A) -> gated out-proj.  Training/prefill
uses an associative scan (O(log T) depth, TPU-friendly); decode is an O(1)
state update — which is what makes the `long_500k` cell runnable for hybrid
archs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.lm_config import LMConfig


def ssm_specs(cfg: LMConfig) -> Dict[str, ParamSpec]:
    d, di, n = cfg.d_model, cfg.d_inner_ssm, cfg.ssm_state
    pd = cfg.pdtype
    return {
        "w_in": ParamSpec((d, 2 * di), ("embed", "heads_qkv"), dtype=pd),
        "conv": ParamSpec((cfg.ssm_conv, di), (None, "heads_qkv"),
                          init="scaled", scale=1.0, dtype=pd),
        "w_dt": ParamSpec((di, di), ("heads_qkv", "heads_qkv"),
                          init="scaled", scale=0.1, dtype=pd),
        "dt_bias": ParamSpec((di,), ("heads_qkv",), init="zeros", dtype=pd),
        "w_bc": ParamSpec((di, 2 * n), ("heads_qkv", None), dtype=pd),
        "a_log": ParamSpec((di, n), ("heads_qkv", None), init="zeros",
                           dtype=jnp.float32),
        "d_skip": ParamSpec((di,), ("heads_qkv",), init="ones", dtype=pd),
        "w_out": ParamSpec((di, d), ("heads_qkv", "embed"), dtype=pd),
    }


def _conv_scan(x: jax.Array, conv_w: jax.Array) -> jax.Array:
    """Causal depthwise conv over seq: x [B,S,di], conv_w [K,di]."""
    K = conv_w.shape[0]
    pads = [jnp.pad(x, ((0, 0), (K - 1 - i, i), (0, 0)))[:, :x.shape[1], :]
            for i in range(K)]
    out = sum(p * conv_w[K - 1 - i] for i, p in enumerate(pads))
    return jax.nn.silu(out)


def _selective_terms(params, xc, cfg: LMConfig):
    """Common dt/B/C/A terms.  xc [..., di] (post-conv)."""
    n = cfg.ssm_state
    dt = jax.nn.softplus(xc @ params["w_dt"].astype(xc.dtype)
                         + params["dt_bias"].astype(xc.dtype))    # [...,di]
    bc = xc @ params["w_bc"].astype(xc.dtype)                     # [...,2n]
    b, c = bc[..., :n], bc[..., n:]
    a = -jnp.exp(params["a_log"])                                 # [di,n] f32
    dt32 = dt.astype(jnp.float32)
    a_bar = jnp.exp(dt32[..., None] * a)                          # [...,di,n]
    bx = (dt32[..., None] * b.astype(jnp.float32)[..., None, :]
          * xc.astype(jnp.float32)[..., None])                    # [...,di,n]
    return a_bar, bx, c, dt


def ssm_branch(params, x: jax.Array, cfg: LMConfig) -> jax.Array:
    """Training/prefill: x [B,S,D] -> [B,S,D] via associative scan."""
    di = cfg.d_inner_ssm
    h = x @ params["w_in"].astype(x.dtype)                        # [B,S,2di]
    xin, z = h[..., :di], h[..., di:]
    xc = _conv_scan(xin, params["conv"].astype(x.dtype))
    a_bar, bx, c, _ = _selective_terms(params, xc, cfg)           # [B,S,di,n]

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, hs = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", hs,
                   c.astype(jnp.float32)).astype(x.dtype)
    y = y + params["d_skip"].astype(x.dtype) * xc
    y = y * jax.nn.silu(z)
    return y @ params["w_out"].astype(x.dtype)


def ssm_decode(params, x: jax.Array, state: Dict[str, jax.Array],
               cfg: LMConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode.  x [B,1,D]; state: conv window [B,K-1,di] and
    ssm state h [B,di,n] (f32)."""
    di = cfg.d_inner_ssm
    hproj = x @ params["w_in"].astype(x.dtype)
    xin, z = hproj[..., :di], hproj[..., di:]                     # [B,1,di]
    window = jnp.concatenate([state["conv"], xin], axis=1)        # [B,K,di]
    # prefill's causal conv puts conv[0] on the CURRENT token; window is
    # ordered oldest->newest, so flip the taps to match
    conv_w = params["conv"][::-1].astype(x.dtype)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, conv_w))[:, None, :]
    a_bar, bx, c, _ = _selective_terms(params, xc, cfg)           # [B,1,di,n]
    h_new = state["h"] * a_bar[:, 0] + bx[:, 0]                   # [B,di,n]
    y = jnp.einsum("bdn,bn->bd", h_new,
                   c[:, 0].astype(jnp.float32))[:, None, :].astype(x.dtype)
    y = y + params["d_skip"].astype(x.dtype) * xc
    y = y * jax.nn.silu(z)
    out = y @ params["w_out"].astype(x.dtype)
    return out, {"conv": window[:, 1:], "h": h_new}


def init_ssm_state(cfg: LMConfig, batch: int, n_layers: int
                   ) -> Dict[str, jax.Array]:
    di, n, k = cfg.d_inner_ssm, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": jnp.zeros((n_layers, batch, k - 1, di), cfg.adtype),
        "h": jnp.zeros((n_layers, batch, di, n), jnp.float32),
    }
