"""Dense MLP blocks (SwiGLU / GELU) with optional IRC projection mode."""
from __future__ import annotations

from typing import Dict

import jax

from repro.models.common import ParamSpec
from repro.models.lm_config import LMConfig


def mlp_specs(cfg: LMConfig, d_ff: int = 0) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    pd = cfg.pdtype
    if cfg.act == "swiglu":
        return {
            "w_gate": ParamSpec((d, ff), ("embed", "mlp"), dtype=pd),
            "w_up": ParamSpec((d, ff), ("embed", "mlp"), dtype=pd),
            "w_down": ParamSpec((ff, d), ("mlp", "embed"), dtype=pd),
        }
    return {
        "w_up": ParamSpec((d, ff), ("embed", "mlp"), dtype=pd),
        "w_down": ParamSpec((ff, d), ("mlp", "embed"), dtype=pd),
    }


def mlp(params: Dict[str, jax.Array], x: jax.Array, cfg: LMConfig) -> jax.Array:
    from jax.ad_checkpoint import checkpoint_name
    dt = x.dtype
    if cfg.act == "swiglu":
        # named for the selective remat policy (remat="names"): saving the
        # TP-sharded projection outputs skips most matmul recompute at a
        # fraction of full dot-saving memory (EXPERIMENTS §Perf cell 1)
        gate = checkpoint_name(x @ params["w_gate"].astype(dt), "mlp_gate")
        up = checkpoint_name(x @ params["w_up"].astype(dt), "mlp_up")
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(checkpoint_name(x @ params["w_up"].astype(dt),
                                        "mlp_up"))
    return h @ params["w_down"].astype(dt)
