"""Shared NN building blocks and the ParamSpec parameter system.

ParamSpec tables are the single source of truth for parameter shapes AND
logical sharding axes: `materialize` turns a spec tree into initialized
arrays, `logical_axes_tree` extracts the matching axes pytree, and
`repro.sharding.rules` maps logical axes -> mesh PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axis names, len == ndim
    init: str = "normal"                  # normal | zeros | ones | scaled
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def materialize(key: jax.Array, specs) -> Any:
    """Initialize a pytree of ParamSpec into arrays (same treedef)."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, s.dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, s.dtype))
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = s.scale / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, s.shape, jnp.float32) * std
                        ).astype(s.dtype))
    return jax.tree.unflatten(treedef, out)


def abstract(specs) -> Any:
    """ShapeDtypeStruct tree matching `materialize` (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def logical_axes_tree(specs) -> Any:
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ------------------------------------------------------------------ numerics

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    """RMSNorm with f32 STATISTICS but activation-dtype elementwise math.

    Upcasting the whole residual stream to f32 (`x.astype(f32)` then
    normalize) makes XLA place the row-parallel TP partial-sum all-reduces
    AFTER the f32 convert — doubling the dominant collective bytes of
    large-model training (measured on llama3-405b).  Computing only the
    variance reduction in f32 keeps the residual (and its all-reduces) in
    bf16, which is the standard large-model scheme.
    """
    dt = x.dtype
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    scale = jax.lax.rsqrt(var + eps).astype(dt)
    g = gamma.astype(dt)
    if plus_one:        # gemma-style (1 + gamma)
        g = (1.0 + gamma.astype(jnp.float32)).astype(dt)
    return x * scale * g


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.  x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]                       # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    half = d_model // 2
    freq = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None,
                       z_loss: float = 1e-4) -> Tuple[jax.Array, Dict]:
    """Token-level CE in f32 with optional z-loss; labels [B,S] int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    zl = z_loss * jnp.square(lse)
    per_tok = nll + zl
    if mask is None:
        mask = jnp.ones_like(nll)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(per_tok * mask) / denom
    metrics = {"nll": jnp.sum(nll * mask) / denom,
               "z_loss": jnp.sum(zl * mask) / denom}
    return loss, metrics
