"""The paper's object-detection model (Fig. 11): YOLOv2-style backbone of
binary GROUP convolutions (group size 60) mapped onto IRC macros.

Two designs, matching the paper's ablation:
  * baseline: binary weights + in-memory BN + partial-sum accumulation
  * proposed: ternary weights (20/60/20), NO BN, single-shot accumulation,
    extra common-mode bias rows

Execution paths:
  * mode="train": differentiable QAT (STE quantizers + noise surrogate)
  * mode="eval":  full structural crossbar simulation per group (each group
    channel = one differential column pair; fan-in 3*3*60=540 cells + bias
    rows, exactly the paper's 636-cell mapping arithmetic)

First (stem) and last (head) layers are digital, as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import nonideal as ni
from repro.core.crossbar import crossbar_forward
from repro.core.macro import MacroSpec, DEFAULT_MACRO
from repro.core.mapping import ternary_planes, binary_planes, fold_bn_to_bias_units
from repro.core.ternary import (ternary_quantize, binary_quantize,
                                binary_activation)
from repro.models.common import ParamSpec, materialize, logical_axes_tree

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    img_hw: Tuple[int, int] = (576, 1024)     # paper: 1024x576 (w x h)
    n_classes: int = 3                        # IVS 3cls
    n_anchors: int = 5
    group: int = 60                           # paper's group size
    # channel plan: stem -> stages (each stage = GConv blocks + downsample)
    stage_channels: Tuple[int, ...] = (60, 120, 240, 480)
    blocks_per_stage: Tuple[int, ...] = (1, 2, 2, 2)
    scheme: str = "ternary"                   # proposed | "binary" baseline
    use_bn: bool = False                      # baseline: in-memory BN
    accumulation: str = "single_shot"         # baseline: "partial_sum"
    bias_rows: int = 32
    partial_rows: int = 212                   # ~300uA limit at nominal V_WL
    dtype: Any = jnp.float32

    def __post_init__(self):
        # The PRNG layer_id lattice `s * 10 + b` (declared in
        # repro.analysis.keys.DECLARED_FOLD_LATTICES) is injective only
        # while every stage has fewer than 10 blocks; a deeper stage would
        # silently alias chip noise across layers.
        if any(nb >= 10 for nb in self.blocks_per_stage):
            raise ValueError(
                f"blocks_per_stage {self.blocks_per_stage} breaks the "
                f"s*10+b layer_id key lattice (needs every stage < 10 "
                f"blocks)")
        if len(self.blocks_per_stage) != len(self.stage_channels):
            raise ValueError(
                f"blocks_per_stage {self.blocks_per_stage} and "
                f"stage_channels {self.stage_channels} must align")

    @property
    def strides(self) -> int:
        return 2 ** (len(self.stage_channels) + 1)   # stem /2 + pools


class IRCDetector:
    """init/apply for the detector; `apply` returns raw head predictions
    [B, gh, gw, A*(5+C)]."""

    def __init__(self, cfg: DetectorConfig, spec: MacroSpec = DEFAULT_MACRO):
        self.cfg = cfg
        self.spec = spec

    def head_geometry(self) -> Tuple[int, int, int]:
        """(gh, gw, head_out) of `apply`'s raw predictions: the output grid
        after the stem + per-stage pools and the per-cell channel count
        `n_anchors * (5 + n_classes)`.  The serving engine, the shape
        contracts, and the decode helpers all derive prediction shapes from
        this one place."""
        cfg = self.cfg
        return (cfg.img_hw[0] // cfg.strides, cfg.img_hw[1] // cfg.strides,
                cfg.n_anchors * (5 + cfg.n_classes))

    # ------------------------------------------------------------ params
    def specs(self) -> Dict[str, PyTree]:
        cfg = self.cfg
        out: Dict[str, PyTree] = {
            # digital stem: 3x3 s2 conv to first stage width
            "stem": ParamSpec((3, 3, 3, cfg.stage_channels[0]),
                              (None, None, None, "mlp"), dtype=cfg.dtype),
            # stem BN carries running stats: eval mode must normalize with
            # CALIBRATION statistics (batch statistics at eval would make
            # outputs depend on batch composition — see `calibrate_bn`)
            "stem_bn": {"gamma": ParamSpec((cfg.stage_channels[0],), ("mlp",),
                                           init="ones", dtype=cfg.dtype),
                        "beta": ParamSpec((cfg.stage_channels[0],), ("mlp",),
                                          init="zeros", dtype=cfg.dtype),
                        "mean": ParamSpec((cfg.stage_channels[0],), ("mlp",),
                                          init="zeros", dtype=cfg.dtype),
                        "var": ParamSpec((cfg.stage_channels[0],), ("mlp",),
                                         init="ones", dtype=cfg.dtype)},
        }
        for s, (ch, nb) in enumerate(zip(cfg.stage_channels,
                                         cfg.blocks_per_stage)):
            c_in = cfg.stage_channels[max(0, s - 1)] if s else ch
            for b in range(nb):
                cin = c_in if b == 0 else ch
                blk: Dict[str, PyTree] = {
                    "w": ParamSpec((3 * 3 * cfg.group, cfg.group,
                                    max(cin, ch) // cfg.group),
                                   (None, "mlp", None), dtype=cfg.dtype),
                }
                if cfg.use_bn:
                    blk["bn"] = {
                        "gamma": ParamSpec((ch,), ("mlp",), init="ones",
                                           dtype=cfg.dtype),
                        "beta": ParamSpec((ch,), ("mlp",), init="zeros",
                                          dtype=cfg.dtype),
                        "mean": ParamSpec((ch,), ("mlp",), init="zeros",
                                          dtype=cfg.dtype),
                        "var": ParamSpec((ch,), ("mlp",), init="ones",
                                         dtype=cfg.dtype),
                    }
                out[f"s{s}b{b}"] = blk
        head_in = cfg.stage_channels[-1]
        out["head"] = ParamSpec(
            (1 * 1 * head_in, cfg.n_anchors * (5 + cfg.n_classes)),
            (None, "mlp"), dtype=cfg.dtype)
        out["head_b"] = ParamSpec((cfg.n_anchors * (5 + cfg.n_classes),),
                                  ("mlp",), init="zeros", dtype=cfg.dtype)
        return out

    def init(self, key: jax.Array) -> PyTree:
        return materialize(key, self.specs())

    def logical_axes(self) -> PyTree:
        return logical_axes_tree(self.specs())

    # ------------------------------------------------------------ blocks
    def _gconv_weights(self, blk: PyTree, cin: int, cout: int) -> jax.Array:
        """Per-group latent weights [(g) 540, group, n_groups] -> quantized
        full conv kernel [3,3,cin,cout] (block-diagonal across groups)."""
        cfg = self.cfg
        w = blk["w"]                         # [540, group, n_groups]
        n_groups = cout // cfg.group
        if cfg.scheme == "ternary":
            wq = ternary_quantize(w, axis=(0, 1))
        else:
            wq = binary_quantize(w)
        # assemble block-diagonal grouped kernel
        wq = wq.reshape(3, 3, cfg.group, cfg.group, n_groups)
        return wq

    def _gconv_pre(self, blk: PyTree, x4: jax.Array, cin: int, cout: int
                   ) -> Tuple[jax.Array, jax.Array]:
        """Differentiable QAT pre-activation shared by the single-draw and
        ensemble train paths: quantized grouped conv + (baseline) BN with
        the sign-preserving |gamma| fold.  [N,H,W,cin] -> ([N,H,W,cout],
        quantized kernel [3,3,g,g,ng]); the ensemble path folds its chips
        axis into N before calling."""
        cfg = self.cfg
        n_groups = cout // cfg.group
        wq = self._gconv_weights(blk, cin, cout)       # [3,3,g,g,ng]
        xg = x4.reshape(x4.shape[:-1] + (n_groups, cfg.group))
        outs = [jax.lax.conv_general_dilated(
            xg[..., g, :], wq[..., g], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
            for g in range(n_groups)]
        pre = jnp.concatenate(outs, axis=-1)           # [N,H,W,cout]
        if cfg.use_bn:
            bn = blk["bn"]
            mu = jnp.mean(pre, axis=(0, 1, 2))
            var = jnp.var(pre, axis=(0, 1, 2))
            # |gamma|: the in-memory BN fold (Fig. 13a) is only
            # sign-preserving for positive gamma, so the baseline QAT
            # constrains it (standard BNN-BN folding practice)
            pre = (jnp.abs(bn["gamma"]) * (pre - mu)
                   / jnp.sqrt(var + 1e-5) + bn["beta"])
        return pre, wq

    def _gconv(self, blk: PyTree, x: jax.Array, cin: int, cout: int, *,
               mode: str, key: jax.Array, cfg_ni: ni.NonidealConfig,
               sa_extra: float = 0.0, device=None) -> jax.Array:
        """Binary group conv + (baseline) BN + binary activation."""
        cfg = self.cfg
        # inputs are {0,1} activations from the previous layer
        if mode == "train":
            pre, wq = self._gconv_pre(blk, x, cin, cout)
            if cfg_ni.any():
                # QAT noise surrogate at the pre-activation level.  The
                # activated-LRS fraction comes from the quantized weights
                # (ternary 20/60/20 -> ~0.4, binary -> ~1.0), as in
                # `irc_linear_train`: the baseline's differential pairs are
                # ~100% LRS-active, so a hardcoded ternary fraction would
                # understate its p_pair.
                lrs_frac = jnp.mean(jnp.abs(jax.lax.stop_gradient(wq)))
                p_pair = jnp.sum(jax.lax.stop_gradient(x), axis=-1,
                                 keepdims=True) * lrs_frac * 9.0 / cin * cfg.group
                std = 0.0
                if cfg_ni.device_variation:
                    from repro.core.crossbar import variation_noise_std
                    std = std + variation_noise_std(p_pair, self.spec.sigma_lrs)
                if cfg_ni.sa_variation:
                    std = std + 0.5 * ni.sa_required_diff(p_pair, self.spec)
                if cfg_ni.device_variation or cfg_ni.sa_variation:
                    pre = pre + std * jax.random.normal(key, pre.shape)
            return binary_activation(pre)
        return self._gconv_structural(blk, x, cin, cout, key=key,
                                      cfg_ni=cfg_ni, sa_extra=sa_extra,
                                      device=device)

    def group_mappings(self, blk: PyTree, cin: int, cout: int) -> List:
        """Per-group `MappedLayer`s of one block (static per deployment).

        Shared by the single-chip structural path and the chip-ensemble
        builder (`repro.mc.detector_mc`): im2col row order is spatial-major,
        rows = (9, group), plus the scheme's bias / in-memory-BN rows.
        """
        cfg, spec = self.cfg, self.spec
        n_groups = cout // cfg.group
        wq = jax.lax.stop_gradient(self._gconv_weights(blk, cin, cout))
        wq = wq.reshape(9, cfg.group, cfg.group, n_groups)
        mappeds = []
        for g in range(n_groups):
            w_flat = wq[..., g].reshape(9 * cfg.group, cfg.group)
            if cfg.scheme == "ternary":
                mapped = ternary_planes(w_flat, bias_rows=cfg.bias_rows)
            else:
                bn_units = None
                if cfg.use_bn:
                    bn = blk["bn"]
                    sl = slice(g * cfg.group, (g + 1) * cfg.group)
                    bn_units = fold_bn_to_bias_units(
                        jnp.abs(bn["gamma"][sl]), bn["beta"][sl],
                        bn["mean"][sl], bn["var"][sl])
                mapped = binary_planes(w_flat, bn_bias_units=bn_units,
                                       spec=spec)
            mappeds.append(mapped)
        return mappeds

    def _im2col_groups(self, x: jax.Array, cin: int, n_groups: int
                       ) -> jax.Array:
        """[..., H, W, cin] {0,1} activations -> [..., H, W, n_groups,
        9*group] word-line patterns (spatial-major rows, matching
        `group_mappings`).  Leading dims beyond the batch (e.g. a chips
        axis) pass through untouched."""
        cfg = self.cfg
        lead = x.shape[:-3]
        H, W = x.shape[-3:-1]
        flat = x.reshape((-1,) + x.shape[-3:])
        patches = jax.lax.conv_general_dilated_patches(
            flat, (3, 3), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))   # [N,H,W,cin*9]
        patches = patches.reshape(lead + (H, W, cin, 9))
        xg = patches.reshape(lead + (H, W, n_groups, cfg.group, 9))
        return jnp.swapaxes(xg, -1, -2).reshape(
            lead + (H, W, n_groups, 9 * cfg.group))

    def _gconv_structural(self, blk: PyTree, x: jax.Array, cin: int,
                          cout: int, *, key: jax.Array,
                          cfg_ni: ni.NonidealConfig,
                          sa_extra: float = 0.0, device=None) -> jax.Array:
        """Full crossbar sim: im2col per group -> mapped planes -> SA bits."""
        cfg, spec = self.cfg, self.spec
        n_groups = cout // cfg.group
        B, H, W, _ = x.shape
        xg = self._im2col_groups(x, cin, n_groups)     # [B,H,W,ng,540]
        outs = []
        for g, mapped in enumerate(self.group_mappings(blk, cin, cout)):
            out = crossbar_forward(jax.random.fold_in(key, g),
                                   xg[..., g, :].reshape(B * H * W, -1),
                                   mapped, cfg=cfg_ni, spec=spec,
                                   accumulation=cfg.accumulation,
                                   partial_rows=cfg.partial_rows,
                                   sa_extra_units=sa_extra, device=device)
            outs.append(out.reshape(B, H, W, cfg.group))
        return jnp.concatenate(outs, axis=-1)

    def _gconv_ensemble(self, groups, x: jax.Array, cin: int, cout: int, *,
                        cfg_ni: ni.NonidealConfig,
                        sa_extra: float = 0.0,
                        output: str = "binary",
                        use_kernel: Optional[bool] = None,
                        kernel_impl: str = "pallas", device=None) -> jax.Array:
        """Ensemble-mode group conv: one vmapped `ensemble_apply` per group
        services every chip of a `DetectorEnsemble` layer.

        x is [B,H,W,cin] (chip-shared input — the first IRC layer; the
        chip-shared activated-LRS counts hoist out of the chips vmap) or
        [chips,B,H,W,cin] (chip-diverged activations downstream).  Returns
        [chips,B,H,W,cout]; chip `c` is bit-identical to the single-chip
        structural path with the corresponding folded key.

        `output` passes through to `ensemble_apply`: "binary" (eval-mode SA
        decisions) or "diff" (raw analog difference — how the train-ensemble
        path turns deviation planes into per-chip pre-activation errors).

        `use_kernel` routes the grouped im2col matmuls onto the fused
        chip-batched Pallas kernel (`ensemble_apply_kernel`, bit-identical
        on the binary/all-effects-off contracts pinned by
        tests/test_kernel_detector.py).  None (default) consults the
        committed autotuning table: the kernel runs only on geometries where
        a sweep on this backend measured it faster (single-shot accumulation
        only — the kernel's fused epilogue).  Forcing True with another
        accumulation mode raises.  `kernel_impl="ref"` swaps in the kernel's
        jnp oracle (interpret-free CI coverage of the routed path).
        """
        from repro.mc.engine import ensemble_apply, ensemble_apply_kernel
        from repro.kernels import autotune
        cfg = self.cfg
        n_groups = cout // cfg.group
        per_chip = x.ndim == 5
        B, H, W = x.shape[-4], x.shape[-3], x.shape[-2]
        xg = self._im2col_groups(x, cin, n_groups)
        if use_kernel and cfg.accumulation != "single_shot":
            raise ValueError(
                "use_kernel=True requires single_shot accumulation (fused "
                f"kernel epilogue); got {cfg.accumulation!r}")
        outs = []
        for g, ens in enumerate(groups):
            x_bits = xg[..., g, :].reshape(
                (x.shape[0], -1, 9 * cfg.group) if per_chip
                else (-1, 9 * cfg.group))
            route = use_kernel
            if route is None:
                # the kernel's fused epilogue bakes the ANALYTIC periphery;
                # auto-routing never picks it for a backend with its own
                route = (cfg.accumulation == "single_shot"
                         and (device is None or device.analytic_periphery)
                         and autotune.kernel_wins(ens.n_chips,
                                                  x_bits.shape[-2],
                                                  ens.n_out, ens.rows))
            if route:
                bm, bn, bk = autotune.best_blocks(ens.n_chips,
                                                  x_bits.shape[-2],
                                                  ens.n_out, ens.rows)
                out = ensemble_apply_kernel(ens, x_bits, cfg=cfg_ni,
                                            spec=self.spec,
                                            sa_extra_units=sa_extra,
                                            output=output,
                                            per_chip_x=per_chip,
                                            impl=kernel_impl,
                                            bm=bm, bn=bn, bk=bk,
                                            device=device)
            else:
                out = ensemble_apply(ens, x_bits, cfg=cfg_ni, spec=self.spec,
                                     accumulation=cfg.accumulation,
                                     partial_rows=cfg.partial_rows,
                                     sa_extra_units=sa_extra,
                                     output=output,
                                     per_chip_x=per_chip, device=device)
            outs.append(out.reshape(out.shape[0], B, H, W, cfg.group))
        return jnp.concatenate(outs, axis=-1)

    def _gconv_train_ensemble(self, blk: PyTree, groups, x: jax.Array,
                              cin: int, cout: int, *, key: jax.Array,
                              cfg_ni: ni.NonidealConfig,
                              use_kernel: Optional[bool] = None,
                              kernel_impl: str = "pallas",
                              device=None) -> jax.Array:
        """Ensemble-aware QAT group conv (paper Sec. V at population scale).

        The differentiable `mode="train"` pre-activation — chips axis folded
        into the batch so ONE conv serves every chip — plus, per chip of the
        pre-sampled deviation population (`repro.mc.build_train_ensemble`):

          * the chip's FROZEN linear device-variation error, computed by the
            shared ensemble machinery on (effective - nominal) conductance
            deltas (`output="diff"`, no stochastic terms) and added under
            stop_gradient exactly like the legacy noise surrogate;
          * a fresh per-read SA-offset draw (std 0.5*g(p_pair)) keyed
            `fold_in(block_key, chip_id)` so a chip's slice is invariant to
            the ensemble it is evaluated in.

        x is [B,H,W,cin] (chip-shared) or [chips,B,H,W,cin] downstream;
        returns [chips,B,H,W,cout] binary activations.
        """
        cfg = self.cfg
        n_chips = groups[0].n_chips
        xf = x.reshape((-1,) + x.shape[-3:])           # fold chips into batch
        pre, wq = self._gconv_pre(blk, xf, cin, cout)
        pre = pre.reshape(x.shape[:-1] + (cout,))
        if cfg_ni.device_variation:
            dev = self._gconv_ensemble(groups, x, cin, cout,
                                       cfg_ni=ni.NonidealConfig.none(),
                                       output="diff",
                                       use_kernel=use_kernel,
                                       kernel_impl=kernel_impl,
                                       device=device)
            pre = pre + jax.lax.stop_gradient(dev)     # adds the chips axis
        if pre.ndim == 4:                              # no variation term:
            pre = jnp.broadcast_to(pre[None], (n_chips,) + pre.shape)
        if cfg_ni.sa_variation:
            lrs_frac = jnp.mean(jnp.abs(jax.lax.stop_gradient(wq)))
            p_pair = jnp.sum(jax.lax.stop_gradient(x), axis=-1,
                             keepdims=True) * lrs_frac * 9.0 / cin * cfg.group
            std = 0.5 * ni.sa_required_diff(p_pair, self.spec)
            eps = jax.vmap(lambda c: jax.random.normal(
                jax.random.fold_in(key, c), pre.shape[1:]))(
                groups[0].chip_ids)
            pre = pre + std * eps
        return binary_activation(pre)

    # ------------------------------------------------------------ BN calib
    def calibrate_bn(self, params: PyTree, images: jax.Array,
                     key: Optional[jax.Array] = None) -> PyTree:
        """Populate BN running stats from a calibration batch.

        BOTH designs need the digital stem's running stats: eval mode
        normalizes with them (batch statistics at eval would tie outputs to
        batch composition).  The baseline additionally stores each block's
        in-memory BN stats, which `binary_planes` folds into bias cells at
        deployment; the block propagation uses |gamma|, matching the
        sign-preserving fold of the train path and the mapping.
        """
        cfg = self.cfg
        params = jax.tree.map(lambda x: x, params)  # shallow copy
        x = jax.lax.conv_general_dilated(
            images.astype(cfg.dtype), params["stem"], (2, 2), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        bn = dict(params["stem_bn"])
        mu, var = jnp.mean(x, (0, 1, 2)), jnp.var(x, (0, 1, 2))
        bn["mean"], bn["var"] = mu, var
        params["stem_bn"] = bn
        if not cfg.use_bn:
            return params
        x = binary_activation(bn["gamma"] * (x - mu) / jnp.sqrt(var + 1e-5)
                              + bn["beta"])
        for s, (ch, nb) in enumerate(zip(cfg.stage_channels,
                                         cfg.blocks_per_stage)):
            c_in = cfg.stage_channels[max(0, s - 1)] if s else ch
            for b in range(nb):
                cin = c_in if b == 0 else ch
                if cin < ch:
                    x = jnp.concatenate([x] * (ch // cin), axis=-1)
                    cin = ch
                blk = dict(params[f"s{s}b{b}"])
                wq = self._gconv_weights(blk, cin, ch)
                xg = x.reshape(x.shape[:-1] + (ch // cfg.group, cfg.group))
                outs = [jax.lax.conv_general_dilated(
                    xg[..., g, :], wq[..., g], (1, 1), "SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
                    for g in range(ch // cfg.group)]
                pre = jnp.concatenate(outs, axis=-1)
                mu, var = jnp.mean(pre, (0, 1, 2)), jnp.var(pre, (0, 1, 2))
                bnp = dict(blk["bn"])
                bnp["mean"], bnp["var"] = mu, var
                blk["bn"] = bnp
                params[f"s{s}b{b}"] = blk
                pre = (jnp.abs(bnp["gamma"]) * (pre - mu)
                       / jnp.sqrt(var + 1e-5) + bnp["beta"])
                x = binary_activation(pre)
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "SAME")
        return params

    # ------------------------------------------------------------ forward
    def apply(self, params: PyTree, images: jax.Array, *, mode: str = "train",
              key: Optional[jax.Array] = None,
              cfg_ni: ni.NonidealConfig = ni.NonidealConfig.none(),
              sa_extra: float = 0.0, ensemble=None,
              use_kernel: Optional[bool] = None,
              kernel_impl: str = "pallas", device=None) -> jax.Array:
        """images [B,H,W,3] in [0,1] -> head predictions [B,gh,gw,A*(5+C)].

        mode="train": differentiable QAT; mode="eval": single-chip structural
        sim (chip identity = `key`); mode="ensemble": every chip of a
        pre-sampled `repro.mc.DetectorEnsemble` at once — returns
        [chips,B,gh,gw,A*(5+C)], chip `c` bit-identical to mode="eval" with
        key `fold_in(base_key, c)`; mode="train_ensemble": differentiable
        ensemble-aware QAT — `ensemble` carries DEVIATION planes
        (`repro.mc.build_train_ensemble`) and the returned
        [chips,B,gh,gw,A*(5+C)] predictions see each chip's frozen variation
        error plus fresh per-read SA noise (chips folded into the batch by
        the loss).

        `use_kernel`/`kernel_impl` (ensemble modes only) control the
        Pallas-kernel routing of the grouped crossbar matmuls — see
        `_gconv_ensemble`; None defers to the committed autotuning table.

        `device` is the `repro.device` backend for the structural/ensemble
        periphery terms (None: analytic); an ensemble's PLANES already carry
        the backend they were sampled with, so pass the same backend here.
        The `mode="train"` noise surrogate stays analytic by design — it is
        a calibrated QAT proxy, not a physics path.
        """
        cfg = self.cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        x = jax.lax.conv_general_dilated(
            images.astype(cfg.dtype), params["stem"], (2, 2), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        bn = params["stem_bn"]
        if mode in ("train", "train_ensemble"):
            mu = jnp.mean(x, axis=(0, 1, 2))
            var = jnp.var(x, axis=(0, 1, 2))
        else:
            # eval/ensemble: running stats from `calibrate_bn` — batch
            # statistics here would make deployed outputs depend on batch
            # composition (and MC chunking would change the metric)
            mu, var = bn["mean"], bn["var"]
        x = bn["gamma"] * (x - mu) / jnp.sqrt(var + 1e-5) + bn["beta"]
        x = binary_activation(x)

        for s, (ch, nb) in enumerate(zip(cfg.stage_channels,
                                         cfg.blocks_per_stage)):
            c_in = cfg.stage_channels[max(0, s - 1)] if s else ch
            for b in range(nb):
                cin = c_in if b == 0 else ch
                if cin < ch:   # widen by repetition before the block
                    x = jnp.concatenate([x] * (ch // cin), axis=-1)
                    cin = ch
                if mode == "ensemble":
                    x = self._gconv_ensemble(
                        ensemble.layers[f"s{s}b{b}"], x, cin, ch,
                        cfg_ni=cfg_ni, sa_extra=sa_extra,
                        use_kernel=use_kernel, kernel_impl=kernel_impl,
                        device=device)
                elif mode == "train_ensemble":
                    x = self._gconv_train_ensemble(
                        params[f"s{s}b{b}"], ensemble.layers[f"s{s}b{b}"],
                        x, cin, ch, key=jax.random.fold_in(key, s * 10 + b),
                        cfg_ni=cfg_ni, use_kernel=use_kernel,
                        kernel_impl=kernel_impl, device=device)
                else:
                    x = self._gconv(params[f"s{s}b{b}"], x, cin, ch,
                                    mode=mode,
                                    key=jax.random.fold_in(key, s * 10 + b),
                                    cfg_ni=cfg_ni, sa_extra=sa_extra,
                                    device=device)
            wd = (1,) * (x.ndim - 3) + (2, 2, 1)
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, wd, wd,
                                      "SAME")
        return x @ params["head"] + params["head_b"]
