"""GQA attention with sliding-window / softcap / qk-norm variants.

Grouped layout throughout: q is [B, S, KV, G, hd] (G = q heads per kv head)
so GQA never materializes repeated KV.  Scores/softmax in f32.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, rms_norm, rope, softcap
from repro.models.lm_config import LMConfig


def attn_specs(cfg: LMConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.head_dim
    pd = cfg.pdtype
    specs = {
        "wq": ParamSpec((d, cfg.n_heads * hd), ("embed", "heads_qkv"), dtype=pd),
        "wk": ParamSpec((d, cfg.n_kv_heads * hd), ("embed", "kv_qkv"), dtype=pd),
        "wv": ParamSpec((d, cfg.n_kv_heads * hd), ("embed", "kv_qkv"), dtype=pd),
        "wo": ParamSpec((cfg.n_heads * hd, d), ("heads_qkv", "embed"), dtype=pd),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), (None,), init="ones", dtype=pd)
        specs["k_norm"] = ParamSpec((hd,), (None,), init="ones", dtype=pd)
    return specs


def _qkv(params, x, cfg: LMConfig, positions):
    from jax.ad_checkpoint import checkpoint_name
    B, S, _ = x.shape
    kv, g, hd = cfg.n_kv_heads, cfg.q_per_kv, cfg.head_dim
    q = checkpoint_name(x @ params["wq"].astype(x.dtype),
                        "attn_q").reshape(B, S, kv, g, hd)
    k = checkpoint_name(x @ params["wk"].astype(x.dtype),
                        "attn_k").reshape(B, S, kv, hd)
    v = checkpoint_name(x @ params["wv"].astype(x.dtype),
                        "attn_v").reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if cfg.pos == "rope":
        qf = q.reshape(B, S, kv * g, hd)
        qf = rope(qf, positions, cfg.rope_theta)
        q = qf.reshape(B, S, kv, g, hd)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(q_pos: jax.Array, k_pos: jax.Array, window: Optional[int]
          ) -> jax.Array:
    """[Sq, Sk] additive mask: causal + optional sliding window."""
    causal = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        causal = jnp.logical_and(causal,
                                 q_pos[:, None] - k_pos[None, :] < window)
    return jnp.where(causal, 0.0, -1e30).astype(jnp.float32)


def _sdpa(q, k, v, mask, cfg: LMConfig, g_major: bool = False):
    """q [B,Sq,KV,G,hd], k/v [B,Sk,KV,hd], mask [Sq,Sk] -> [B,Sq,KV*G*hd].

    `g_major=True` merges heads as (G,KV,hd) instead of (KV,G,hd): under
    q-group TP the merged head dim is then contiguous in the sharded G, so
    the reshape preserves the sharding (otherwise XLA re-replicates the
    [B,KV,G,S,S] probs in the backward — measured 137 GB all-gathers per
    layer on llama3-405b).  wo is learned, so the head order is an internal
    layout choice applied consistently in train and decode.
    """
    scale = cfg.head_dim ** -0.5
    # scores accumulate in the MXU's native f32 and round to the activation
    # dtype at output; softmax itself stays f32.  Requesting an f32 RESULT
    # (preferred_element_type) would make every backward cotangent through
    # the q/k/v projections f32 — measured 2x on the dominant row-parallel
    # all-reduces of llama3-405b training.
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * scale
    scores = scores.astype(jnp.float32)
    scores = softcap(scores, cfg.attn_logit_softcap)
    scores = scores + mask
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    B, Sq = out.shape[0], out.shape[1]
    if g_major:
        out = out.transpose(0, 1, 3, 2, 4)      # [B,Sq,G,KV,hd]
    return out.reshape(B, Sq, cfg.n_heads * cfg.head_dim)


def attention(params, x: jax.Array, cfg: LMConfig, *, is_global: jax.Array,
              positions: jax.Array, constrain=None, mode=None,
              out_constrain=None) -> jax.Array:
    """Training/prefill attention.  `is_global` is a traced per-layer bool
    (scan-friendly): local layers see a sliding-window mask.  `constrain`
    (q,k,v)->(q,k,v) pins the TP scheme; `mode` is LM.attn_mode;
    `out_constrain(x, axes)` pins the merged output sharding."""
    S = x.shape[1]
    q, k, v = _qkv(params, x, cfg, positions)
    if constrain is not None:
        q, k, v = constrain(q, k, v)
    pos = positions[0] if positions.ndim > 1 else positions
    full = _mask(pos, pos, None)
    if cfg.attn_pattern != "global":
        local = _mask(pos, pos, cfg.window)
        mask = jnp.where(is_global, full, local)
    else:
        mask = full
    out = _sdpa(q, k, v, mask, cfg, g_major=(mode == "q_groups"))
    if out_constrain is not None:
        out = out_constrain(out, ("act_batch", None, "act_heads"))
    return out @ params["wo"].astype(x.dtype)


def attention_decode(params, x: jax.Array, cache: Dict[str, jax.Array],
                     cfg: LMConfig, *, is_global: jax.Array,
                     cur_index: jax.Array, constrain=None, mode=None,
                     out_constrain=None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode with a KV cache.

    x [B,1,D]; cache {"k": [B,Smax,KV,hd], "v": ...}; cur_index scalar = the
    position being written.  Returns (out [B,1,D], updated cache).
    """
    B = x.shape[0]
    s_max = cache["k"].shape[1]
    positions = jnp.full((B, 1), cur_index, jnp.int32)
    q, k, v = _qkv(params, x, cfg, positions)
    if constrain is not None:
        q, k, v = constrain(q, k, v)
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), cur_index, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), cur_index, axis=1)
    k_pos = jnp.arange(s_max)
    valid = k_pos <= cur_index
    if cfg.attn_pattern != "global":
        in_window = k_pos > cur_index - cfg.window
        valid = jnp.where(is_global, valid, jnp.logical_and(valid, in_window))
    mask = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[None, :]
    out = _sdpa(q, ck.astype(x.dtype), cv.astype(x.dtype), mask, cfg,
                g_major=(mode == "q_groups"))
    if out_constrain is not None:
        out = out_constrain(out, ("act_batch", None, "act_heads"))
    return out @ params["wo"].astype(x.dtype), {"k": ck, "v": cv}


def init_kv_cache(cfg: LMConfig, batch: int, s_max: int, n_layers: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    shape = (n_layers, batch, s_max, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
