"""Decoder-only LM composition: all 10 assigned architectures as one module.

Layers are scanned (`lax.scan` over stacked params): HLO size is O(1) in
depth, FSDP all-gathers overlap per layer, and 126-layer models compile
quickly.  Heterogeneous depth (kimi's dense prefix) is handled by scanning
homogeneous SEGMENTS.  IRC mode (the paper's technique) ternary-quantizes
every projection matmul via STE (QAT) — embeddings/router/norms stay
digital, mirroring the paper's digital first/last layers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.ternary import ternary_quantize
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (ParamSpec, materialize, abstract,
                                 logical_axes_tree, rms_norm, softcap,
                                 sinusoidal_positions, cross_entropy_loss)
from repro.models.lm_config import LMConfig

PyTree = Any

# parameter names that are crossbar-mappable projections (IRC mode)
_IRC_PROJ_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                   "w_in", "w_out", "w_dt", "w_bc", "w_r", "w_k", "w_v",
                   "w_g", "w_o")


def _stack(specs: PyTree, n: int) -> PyTree:
    """Add a leading stacked-layer dimension to every ParamSpec."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes,
                            init=s.init, scale=s.scale, dtype=s.dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _norm_spec(cfg: LMConfig) -> ParamSpec:
    init = "zeros" if cfg.norm_plus_one else "ones"
    return ParamSpec((cfg.d_model,), ("embed",), init=init, dtype=cfg.pdtype)


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str        # "dense" | "moe" | "hybrid" | "rwkv"
    count: int
    layer_offset: int


class LM:
    """Pure-functional LM: `init`, `apply` (logits), `loss`, `decode_step`."""

    def __init__(self, cfg: LMConfig):
        self.cfg = cfg
        self.segments = self._plan_segments()
        # distribution state (None on single-host CPU): set via use_mesh().
        self.mesh = None
        self.act_overrides = None
        self.attn_mode = None
        self.moe_groups = 1

    def use_mesh(self, mesh, act_overrides=None) -> "LM":
        """Enable activation sharding constraints for `mesh`.

        Without explicit constraints XLA's sharding propagation lets the
        FSDP (contracting-dim) parameter sharding leak into activations:
        tokens end up REPLICATED and features sharded, destroying data
        parallelism (measured 16-19x per-device FLOP inflation).  The
        residual stream is therefore pinned to batch-DP at every layer
        boundary.  `act_overrides` remaps logical axes (e.g. sequence
        parallelism) for perf experiments.

        Attention TP mode (assigned head counts don't always divide the
        16-way model axis — the framework picks a valid scheme per arch):
          kv_heads : shard the KV-head dim of q/k/v        (e.g. gemma2 kv=16)
          q_groups : shard q's per-kv group dim, KV replicated
                     (MaxText-style GQA; llama3/qwen3 G=16)
          kv_seq   : context parallelism — shard K/V sequence; softmax
                     and PV contraction reduce over the model axis
                     (phi3 40H, hymba 25H, deepseek/kimi/chameleon kv=8)
        """
        self.mesh = mesh
        self.act_overrides = act_overrides
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        m = sizes.get("model", 1)
        # MoE dispatch groups = DP shard count (group-local capacity)
        self.moe_groups = sizes.get("pod", 1) * sizes.get("data", 1)
        cfg = self.cfg
        if m == 1 or cfg.block == "rwkv":
            self.attn_mode = None
        elif cfg.n_kv_heads % m == 0:
            self.attn_mode = "kv_heads"
        elif cfg.q_per_kv % m == 0:
            self.attn_mode = "q_groups"
        else:
            self.attn_mode = "kv_seq"
        return self

    def _constrain(self, x: jax.Array, axes: Tuple) -> jax.Array:
        if self.mesh is None:
            return x
        from repro.sharding.rules import spec_for_axes
        spec = spec_for_axes(axes, x.shape, self.mesh, self.act_overrides)
        return jax.lax.with_sharding_constraint(x, spec)

    def _attn_constrain(self, q, k, v):
        """Pin the attention TP scheme chosen in use_mesh (see docstring).
        q [B,S,KV,G,hd]; k/v [B,S,KV,hd]."""
        if self.attn_mode is None:
            return q, k, v
        c = self._constrain
        if self.attn_mode == "kv_heads":
            q = c(q, ("act_batch", None, "act_heads", None, None))
            k = c(k, ("act_batch", None, "act_heads", None))
            v = c(v, ("act_batch", None, "act_heads", None))
        elif self.attn_mode == "q_groups":
            q = c(q, ("act_batch", None, None, "act_heads", None))
            k = c(k, ("act_batch", None, None, None))
            v = c(v, ("act_batch", None, None, None))
        else:  # kv_seq: context parallelism over the KV sequence
            q = c(q, ("act_batch", None, None, None, None))
            k = c(k, ("act_batch", "act_seq_model", None, None))
            v = c(v, ("act_batch", "act_seq_model", None, None))
        return q, k, v

    # ------------------------------------------------------------ structure
    def _plan_segments(self) -> List[Segment]:
        cfg = self.cfg
        if cfg.block == "rwkv":
            return [Segment("rwkv", cfg.n_layers, 0)]
        if cfg.block == "hybrid":
            return [Segment("hybrid", cfg.n_layers, 0)]
        if cfg.moe:
            segs = []
            if cfg.n_dense_prefix:
                segs.append(Segment("dense", cfg.n_dense_prefix, 0))
            segs.append(Segment("moe", cfg.n_layers - cfg.n_dense_prefix,
                                cfg.n_dense_prefix))
            return segs
        return [Segment("dense", cfg.n_layers, 0)]

    def _layer_specs(self, kind: str) -> Dict[str, PyTree]:
        cfg = self.cfg
        if kind == "rwkv":
            s = rwkv_mod.rwkv_specs(cfg)
            s["ln1"] = _norm_spec(cfg)
            s["ln2"] = _norm_spec(cfg)
            return s
        specs: Dict[str, PyTree] = {
            "ln1": _norm_spec(cfg),
            "ln2": _norm_spec(cfg),
            "attn": attn_mod.attn_specs(cfg),
        }
        if cfg.post_norm:
            specs["ln1_post"] = _norm_spec(cfg)
            specs["ln2_post"] = _norm_spec(cfg)
        if kind == "moe":
            specs["moe"] = moe_mod.moe_specs(cfg)
        elif kind == "hybrid":
            specs["ssm"] = ssm_mod.ssm_specs(cfg)
            specs["mlp"] = mlp_mod.mlp_specs(cfg)
        else:
            # kimi-style dense prefix uses top_k*d_ff as its dense hidden
            ff = cfg.d_ff * cfg.top_k if cfg.moe else cfg.d_ff
            specs["mlp"] = mlp_mod.mlp_specs(cfg, d_ff=ff)
        return specs

    def specs(self) -> Dict[str, PyTree]:
        cfg = self.cfg
        top: Dict[str, PyTree] = {
            "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"), dtype=cfg.pdtype),
            "final_norm": _norm_spec(cfg),
        }
        if not cfg.tie_embeddings:
            top["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                       ("embed", "vocab"), dtype=cfg.pdtype)
        for i, seg in enumerate(self.segments):
            top[f"seg{i}_{seg.kind}"] = _stack(self._layer_specs(seg.kind),
                                               seg.count)
        return top

    def init(self, key: jax.Array) -> PyTree:
        return materialize(key, self.specs())

    def abstract_params(self) -> PyTree:
        return abstract(self.specs())

    def logical_axes(self) -> PyTree:
        return logical_axes_tree(self.specs())

    # ------------------------------------------------------------ IRC mode
    def _maybe_irc(self, params: PyTree) -> PyTree:
        if not self.cfg.irc.enabled:
            return params

        def quantize(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if name in _IRC_PROJ_NAMES:
                return ternary_quantize(leaf)
            return leaf
        return jax.tree_util.tree_map_with_path(quantize, params)

    # ------------------------------------------------------------ blocks
    def _layer_fwd(self, kind: str, lp: PyTree, x: jax.Array,
                   is_global: jax.Array, positions: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
        """One layer forward (train/prefill). Returns (x, aux_loss)."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if kind == "rwkv":
            B = x.shape[0]
            H, hd = rwkv_mod._heads(cfg)
            st = {"wkv": jnp.zeros((B, H, hd, hd), jnp.float32),
                  "tshift": jnp.zeros((B, cfg.d_model), x.dtype),
                  "cshift": jnp.zeros((B, cfg.d_model), x.dtype)}
            h, _, _ = rwkv_mod.time_mix(lp["time"],
                                        rms_norm(x, lp["ln1"], cfg.norm_eps,
                                                 cfg.norm_plus_one),
                                        cfg, st["tshift"], st["wkv"])
            x = x + h
            h, _ = rwkv_mod.channel_mix(lp["channel"],
                                        rms_norm(x, lp["ln2"], cfg.norm_eps,
                                                 cfg.norm_plus_one),
                                        st["cshift"])
            return x + h, aux

        h = rms_norm(x, lp["ln1"], cfg.norm_eps, cfg.norm_plus_one)
        a = attn_mod.attention(lp["attn"], h, cfg, is_global=is_global,
                               positions=positions,
                               constrain=self._attn_constrain,
                               mode=self.attn_mode,
                               out_constrain=self._constrain
                               if self.mesh is not None else None)
        if kind == "hybrid":
            s = ssm_mod.ssm_branch(lp["ssm"], h, cfg)
            a = 0.5 * (a + s)          # hymba: parallel attn+SSM head fusion
        if cfg.post_norm:
            a = rms_norm(a, lp["ln1_post"], cfg.norm_eps, cfg.norm_plus_one)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps, cfg.norm_plus_one)
        if kind == "moe":
            m, moe_aux = moe_mod.moe_block(lp["moe"], h, cfg,
                                           constrain=self._constrain,
                                           dispatch_groups=self.moe_groups)
            aux = aux + moe_aux["aux_loss"]
        else:
            m = mlp_mod.mlp(lp["mlp"], h, cfg)
        if cfg.post_norm:
            m = rms_norm(m, lp["ln2_post"], cfg.norm_eps, cfg.norm_plus_one)
        return x + m, aux

    # ------------------------------------------------------------ forward
    def apply(self, params: PyTree, tokens: jax.Array, *,
              remat: str = "block", scan_layers: bool = True
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """tokens [B,S] int32 -> (logits [B,S,V], aux metrics).

        scan_layers=False unrolls the layer loop — used by the roofline cost
        probes because XLA's cost_analysis counts a while-loop body ONCE
        regardless of trip count (production lowering always scans)."""
        cfg = self.cfg
        params = self._maybe_irc(params)
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
        if cfg.embed_scale:
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        if cfg.pos == "sinusoidal":
            x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)

        x = self._constrain(x, ("act_batch", "act_seq", "act_embed"))
        aux_total = jnp.zeros((), jnp.float32)
        for i, seg in enumerate(self.segments):
            stacked = params[f"seg{i}_{seg.kind}"]
            flags = jnp.asarray([cfg.layer_is_global(seg.layer_offset + l)
                                 for l in range(seg.count)])

            def body(carry, xs, _kind=seg.kind):
                xc, aux = carry
                lp, flag = xs
                xc = self._constrain(xc, ("act_batch", "act_seq", "act_embed"))
                xc, a = self._layer_fwd(_kind, lp, xc, flag, positions)
                return (xc, aux + a), None

            if remat == "block":
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            elif remat == "dots":
                body = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            elif remat == "names":
                # memory-feasible middle ground: save only the TP-sharded
                # projection outputs (q/k/v/gate/up); recompute the rest
                body = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies.save_only_these_names(
                        "attn_q", "attn_k", "attn_v", "mlp_gate", "mlp_up"))
            if scan_layers:
                (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                                 (stacked, flags))
            else:
                for l in range(seg.count):
                    lp = jax.tree.map(lambda a: a[l], stacked)
                    (x, aux_total), _ = body((x, aux_total), (lp, flags[l]))

        x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.norm_plus_one)
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T.astype(x.dtype)
        else:
            logits = x @ params["unembed"].astype(x.dtype)
        logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
        logits = self._constrain(logits, ("act_batch", "act_seq", "vocab"))
        return logits, {"moe_aux_loss": aux_total}

    def loss(self, params: PyTree, batch: Dict[str, jax.Array], *,
             remat: str = "block", scan_layers: bool = True
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = self.apply(params, batch["tokens"], remat=remat,
                                 scan_layers=scan_layers)
        loss, metrics = cross_entropy_loss(logits, batch["labels"],
                                           batch.get("mask"))
        loss = loss + aux["moe_aux_loss"]
        metrics.update(aux)
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------------------------------------------ decode
    def init_cache(self, batch: int, s_max: int) -> PyTree:
        cfg = self.cfg
        cache: Dict[str, PyTree] = {"index": jnp.zeros((), jnp.int32)}
        for i, seg in enumerate(self.segments):
            name = f"seg{i}_{seg.kind}"
            if seg.kind == "rwkv":
                cache[name] = rwkv_mod.init_rwkv_state(cfg, batch, seg.count)
            elif seg.kind == "hybrid":
                cache[name] = {
                    "kv": attn_mod.init_kv_cache(cfg, batch, s_max, seg.count,
                                                 cfg.adtype),
                    "ssm": ssm_mod.init_ssm_state(cfg, batch, seg.count),
                }
            else:
                cache[name] = attn_mod.init_kv_cache(cfg, batch, s_max,
                                                     seg.count, cfg.adtype)
        return cache

    def decode_step(self, params: PyTree, tokens: jax.Array, cache: PyTree,
                    *, scan_layers: bool = True
                    ) -> Tuple[jax.Array, PyTree]:
        """tokens [B,1] -> (logits [B,1,V], updated cache)."""
        cfg = self.cfg
        params = self._maybe_irc(params)
        B = tokens.shape[0]
        idx = cache["index"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
        if cfg.embed_scale:
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
        if cfg.pos == "sinusoidal":
            pos = jnp.full((B, 1), idx, jnp.int32)
            x = x + sinusoidal_positions(pos, cfg.d_model).astype(x.dtype)

        x = self._constrain(x, ("act_batch", "act_seq", "act_embed"))
        new_cache: Dict[str, PyTree] = {"index": idx + 1}
        for i, seg in enumerate(self.segments):
            name = f"seg{i}_{seg.kind}"
            stacked = params[name]
            flags = jnp.asarray([cfg.layer_is_global(seg.layer_offset + l)
                                 for l in range(seg.count)])

            def body(xc, xs, _kind=seg.kind):
                lp, flag, layer_cache = xs
                xc = self._constrain(xc, ("act_batch", "act_seq", "act_embed"))
                xc, new_lc = self._layer_decode(_kind, lp, xc, flag,
                                                layer_cache, idx)
                return xc, new_lc

            if scan_layers:
                x, new_lc = jax.lax.scan(body, x, (stacked, flags, cache[name]))
            else:
                lcs = []
                for l in range(seg.count):
                    lp = jax.tree.map(lambda a: a[l], stacked)
                    lc_l = jax.tree.map(lambda a: a[l], cache[name])
                    x, lc_new = body(x, (lp, flags[l], lc_l))
                    lcs.append(lc_new)
                new_lc = jax.tree.map(lambda *xs: jnp.stack(xs), *lcs)
            new_cache[name] = new_lc

        x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.norm_plus_one)
        if cfg.tie_embeddings:
            logits = x @ params["embed"].T.astype(x.dtype)
        else:
            logits = x @ params["unembed"].astype(x.dtype)
        logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
        return logits, new_cache

    def _layer_decode(self, kind: str, lp: PyTree, x: jax.Array,
                      is_global: jax.Array, lc: PyTree, idx: jax.Array
                      ) -> Tuple[jax.Array, PyTree]:
        cfg = self.cfg
        if kind == "rwkv":
            h, ts, wkv = rwkv_mod.time_mix(
                lp["time"], rms_norm(x, lp["ln1"], cfg.norm_eps,
                                     cfg.norm_plus_one),
                cfg, lc["tshift"], lc["wkv"])
            x = x + h
            h, cs = rwkv_mod.channel_mix(
                lp["channel"], rms_norm(x, lp["ln2"], cfg.norm_eps,
                                        cfg.norm_plus_one), lc["cshift"])
            return x + h, {"wkv": wkv, "tshift": ts, "cshift": cs}

        h = rms_norm(x, lp["ln1"], cfg.norm_eps, cfg.norm_plus_one)
        kv_cache = lc["kv"] if kind == "hybrid" else lc
        a, new_kv = attn_mod.attention_decode(lp["attn"], h, kv_cache, cfg,
                                              is_global=is_global,
                                              cur_index=idx,
                                              constrain=self._attn_constrain,
                                              mode=self.attn_mode,
                                              out_constrain=self._constrain
                                              if self.mesh is not None
                                              else None)
        new_lc: PyTree = new_kv
        if kind == "hybrid":
            s, new_ssm = ssm_mod.ssm_decode(lp["ssm"], h, lc["ssm"], cfg)
            a = 0.5 * (a + s)
            new_lc = {"kv": new_kv, "ssm": new_ssm}
        if cfg.post_norm:
            a = rms_norm(a, lp["ln1_post"], cfg.norm_eps, cfg.norm_plus_one)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps, cfg.norm_plus_one)
        if kind == "moe":
            m, _ = moe_mod.moe_block(lp["moe"], h, cfg,
                                     constrain=self._constrain,
                                     dispatch_groups=self.moe_groups)
        else:
            m = mlp_mod.mlp(lp["mlp"], h, cfg)
        if cfg.post_norm:
            m = rms_norm(m, lp["ln2_post"], cfg.norm_eps, cfg.norm_plus_one)
        return x + m, new_lc
