"""LMConfig — one config dataclass covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class IRCMode:
    """IRC execution mode for parameter matmuls (the paper's technique as a
    first-class feature on any architecture)."""
    enabled: bool = False
    scheme: str = "ternary"            # ternary (proposed) | binary (baseline)
    bias_rows: int = 32
    accumulation: str = "single_shot"
    # which projections run through the crossbar sim at eval
    project_attn: bool = True
    project_mlp: bool = True


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # block family
    block: str = "attn"                # attn | hybrid (attn+ssm) | rwkv
    # attention pattern: per-layer window; None = global.
    attn_pattern: str = "global"       # global | alt_local_global | local_mostly
    window: int = 4096
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qk_norm: bool = False

    # MLP / MoE
    act: str = "swiglu"                # swiglu | gelu
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_dense_prefix: int = 0            # leading dense layers (kimi-k2)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (hybrid) / RWKV
    ssm_state: int = 16
    ssm_conv: int = 4
    d_ff_rwkv_mult: float = 3.5

    # embeddings / positions
    pos: str = "rope"                  # rope | sinusoidal | none
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embed_scale: bool = False          # gemma multiplies embeds by sqrt(d)

    # norms
    norm_eps: float = 1e-6
    post_norm: bool = False            # gemma2 sandwich norms
    norm_plus_one: bool = False        # gemma (1+gamma) RMSNorm

    # numerics
    dtype: str = "bfloat16"            # activation dtype
    param_dtype: str = "float32"

    # modality frontend stub (musicgen/chameleon): inputs are precomputed
    # token ids in the unified vocab; "embed" -> normal token embedding.
    frontend: str = "embed"

    irc: IRCMode = IRCMode()

    # ------------------------------------------------------------ helpers
    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def d_inner_ssm(self) -> int:
        # hybrid: SSM branch width matches the attention branch width
        return self.attn_dim

    def layer_is_global(self, layer: int) -> bool:
        if self.attn_pattern == "global":
            return True
        if self.attn_pattern == "alt_local_global":
            return layer % 2 == 1      # gemma2: local, global, local, ...
        if self.attn_pattern == "local_mostly":
            # hymba: global attention at first, middle, and last layer
            return layer in (0, self.n_layers // 2, self.n_layers - 1)
        raise ValueError(self.attn_pattern)

    def global_layer_flags(self) -> Tuple[bool, ...]:
        return tuple(self.layer_is_global(l) for l in range(self.n_layers))

    def supports_long_context(self) -> bool:
        """True if decode memory is sub-linear in context (SSM/hybrid/linear)."""
        return self.block in ("hybrid", "rwkv")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n_attn = d * self.attn_dim + 2 * d * self.n_kv_heads * self.head_dim \
            + self.attn_dim * d
        if self.block == "rwkv":
            ffh = int(self.d_ff_rwkv_mult * d) if ff == 0 else ff
            per_layer = 4 * d * d + d * ffh + ffh * d + 10 * d
        elif self.block == "hybrid":
            di = self.d_inner_ssm
            ssm = d * 2 * di + di * d + di * (2 * self.ssm_state + 2) \
                + self.ssm_conv * di
            per_layer = n_attn + ssm + 3 * d * ff
        elif self.moe:
            moe_layers = self.n_layers - self.n_dense_prefix
            dense = 3 * d * ff  # prefix layers use expert-sized ff? no: dense ff
            per_moe = n_attn + self.n_experts * 3 * d * ff + d * self.n_experts
            total_blocks = moe_layers * per_moe + self.n_dense_prefix * (
                n_attn + 3 * d * (ff * self.top_k))
            emb = v * d * (1 if self.tie_embeddings else 2)
            return total_blocks + emb + self.n_layers * 2 * d
        else:
            mlp = 3 * d * ff if self.act == "swiglu" else 2 * d * ff
            per_layer = n_attn + mlp
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + self.n_layers * 2 * d

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.moe:
            return self.param_count()
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n_attn = d * self.attn_dim + 2 * d * self.n_kv_heads * self.head_dim \
            + self.attn_dim * d
        per_moe = n_attn + self.top_k * 3 * d * ff + d * self.n_experts
        moe_layers = self.n_layers - self.n_dense_prefix
        dense_layers = self.n_dense_prefix * (n_attn + 3 * d * ff * self.top_k)
        emb = v * d * (1 if self.tie_embeddings else 2)
        return moe_layers * per_moe + dense_layers + emb
