# repro.launch — mesh construction, multi-pod dry-run, training/serving
# entry points.  NOTE: dryrun.py must be the process entry (python -m
# repro.launch.dryrun) so its XLA_FLAGS device-count override precedes any
# jax initialization.
