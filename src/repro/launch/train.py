"""Distributed training launcher.

On a TPU fleet each host runs this entry point (jax.distributed handles the
cross-host runtime); on this CPU container it runs the same code path on the
host mesh.  Fault tolerance is built in: resume-from-latest checkpoint,
stateless-seeded data (restart-exact), async keep-k saves, straggler
logging.  Elastic restart: if the mesh shape changed since the checkpoint
(node failure -> smaller pool), restore reshards against the new mesh.

  PYTHONPATH=src python -m repro.launch.train --arch hymba-1.5b \
      --variant smoke --steps 50 --batch 8 --seq 128
  (production: --mesh single|multi on a real 256/512-chip fleet)
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.registry import get_config, list_archs
from repro.data import SyntheticLMData
from repro.models import LM
from repro.models.lm_config import IRCMode
from repro.optim import AdamWConfig
from repro.sharding.rules import tree_pspecs
from repro.train import make_train_step
from repro.train.steps import init_train_state, train_state_axes
from repro.train.trainer import Trainer, TrainerConfig


def build_mesh(kind: str):
    if kind in ("single", "multi"):
        from repro.launch.mesh import make_production_mesh
        return make_production_mesh(multi_pod=(kind == "multi"))
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b", choices=list_archs())
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "block", "dots", "names"])
    ap.add_argument("--irc", action="store_true",
                    help="ternary-QAT every projection (the paper's mode)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--weight-decay", type=float, default=1e-3)
    ap.add_argument("--run-dir", default="",
                    help="experiments/<run_id>/ run directory root "
                         "(manifest + metrics.jsonl; '' disables)")
    ap.add_argument("--run-id", default="")
    ap.add_argument("--trace", action="store_true",
                    help="capture a jax.profiler trace into the run dir")
    args = ap.parse_args()

    from repro.obs import maybe_runlog
    obs = maybe_runlog(bool(args.run_dir), f"train-{args.arch}",
                       args=vars(args), root=args.run_dir,
                       run_id=args.run_id or None)
    if obs.path is not None:
        print(f"# run dir: {obs.path}")
    if args.trace:
        obs.start_trace()

    cfg = get_config(args.arch, args.variant)
    if args.irc:
        cfg = dataclasses.replace(cfg, irc=IRCMode(enabled=True))
    mesh = build_mesh(args.mesh)
    lm = LM(cfg)
    if mesh.devices.size > 1:
        lm.use_mesh(mesh)

    state = init_train_state(lm, jax.random.PRNGKey(0))
    if mesh.devices.size > 1:
        shardings = jax.tree.map(
            lambda p: NamedSharding(mesh, p),
            tree_pspecs(train_state_axes(lm), jax.eval_shape(lambda: state),
                        mesh),
            is_leaf=lambda x: hasattr(x, "index_sizes") or
            type(x).__name__ == "PartitionSpec")
        state = jax.device_put(state, shardings)

    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch)
    step_fn = make_train_step(
        lm, opt_cfg=AdamWConfig(weight_decay=args.weight_decay),
        lr_fn=lambda s: jnp.float32(args.lr),
        remat=args.remat, microbatch=args.microbatch)
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps,
                      ckpt_every=max(args.steps // 4, 1),
                      ckpt_dir=args.ckpt_dir,
                      log_every=max(args.steps // 20, 1)),
        step_fn, lambda s: data.batch_for_step(s), state, obs=obs)
    hist = trainer.run()
    print(f"final loss {hist[-1]['loss']:.4f} over {len(hist)} steps "
          f"(resumed at {hist[0]['step']}); "
          f"stragglers: {len(trainer.straggler_steps)}; "
          f"compile {trainer.step_timer.compile_s:.1f}s, "
          f"{trainer.step_timer.rate():.2f} steps/s steady")
    obs.finalize(status="ok", final_loss=hist[-1]["loss"],
                 steps=len(hist),
                 steps_per_sec=trainer.step_timer.rate(),
                 compile_s=trainer.step_timer.compile_s)


if __name__ == "__main__":
    main()
