"""input_specs(): ShapeDtypeStruct stand-ins for every model input of every
(arch x shape) cell — weak-type-correct, shardable, zero allocation.

For modality archs ([audio] musicgen / [vlm] chameleon) the frontend is a
stub per the assignment: inputs are precomputed token ids in the model's
vocab (EnCodec frames / unified text+VQ codes respectively).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models.lm_config import LMConfig
from repro.models.transformer import LM

PyTree = Any


def train_input_specs(cfg: LMConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }


def prefill_input_specs(cfg: LMConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def decode_input_specs(lm: LM, shape: ShapeSpec
                       ) -> Tuple[Any, PyTree]:
    """(tokens [B,1], abstract KV/state cache sized for shape.seq_len)."""
    B = shape.global_batch
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    cache = jax.eval_shape(lambda: lm.init_cache(B, shape.seq_len))
    return tokens, cache


def input_specs(lm: LM, shape: ShapeSpec) -> Dict[str, Any]:
    """Unified entry: the dict of abstract inputs the shape's step takes."""
    if shape.kind == "train":
        return {"batch": train_input_specs(lm.cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_input_specs(lm.cfg, shape)}
    tokens, cache = decode_input_specs(lm, shape)
    return {"tokens": tokens, "cache": cache}
