"""Summarize dry-run artifacts into the EXPERIMENTS.md §Dry-run table.

  PYTHONPATH=src python -m repro.launch.summarize
"""
from __future__ import annotations

import json
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def main():
    rows = []
    for p in sorted(OUT_DIR.glob("*.json")):
        if "probe" in p.name:
            continue
        r = json.loads(p.read_text())
        if r.get("status") == "skipped":
            rows.append((r["arch"], r["shape"], r["mesh"], "SKIP", "", "",
                         "", ""))
            continue
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], r["mesh"], "ERROR", "", "",
                         "", ""))
            continue
        ma = r.get("memory_analysis", {})
        args_gb = ma.get("argument_size_in_bytes", 0) / 1e9
        temp_gb = ma.get("temp_size_in_bytes", 0) / 1e9
        coll = r["collectives"]
        coll_gb = coll["total_bytes"] / 1e9
        kinds = "+".join(k[:2] for k in ("all-gather", "all-reduce",
                                         "reduce-scatter", "all-to-all",
                                         "collective-permute")
                         if coll[k]["count"])
        rows.append((r["arch"], r["shape"], r["mesh"], "ok",
                     f"{args_gb:.2f}", f"{temp_gb:.2f}", f"{coll_gb:.2f}",
                     kinds))

    print("| arch | shape | mesh | status | args GB/dev | temp GB/dev | "
          "collective GB (HLO body) | collective kinds |")
    print("|---|---|---|---|---|---|---|---|")
    for row in rows:
        print("| " + " | ".join(str(c) for c in row) + " |")
    n_ok = sum(1 for r in rows if r[3] == "ok")
    n_skip = sum(1 for r in rows if r[3] == "SKIP")
    n_err = sum(1 for r in rows if r[3] == "ERROR")
    print(f"\n{n_ok} ok / {n_skip} skipped / {n_err} errors")


if __name__ == "__main__":
    main()
