"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) single-pod cell, reconstructs per-device totals from the
two unrolled COST PROBES (XLA's cost_analysis counts while-loop bodies once,
so the production scanned module undercounts by the trip count — the probe
delta method recovers exact per-layer costs):

    body   = probe(L0+1) - probe(L0)          (one extra scanned-family layer)
    prefix = probe(L0) - body                  (embed/head/opt + dense prefix)
    total  = prefix + body * n_scanned_layers  (+ analytic RWKV recurrence)

Terms vs TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
    compute    = HLO_FLOPs_dev / 197e12
    memory     = HLO_bytes_dev / 819e9
    collective = collective_bytes_dev / 50e9
MODEL_FLOPS = 6*N*D (train, dense) / 6*N_active*D (MoE) / 2*N*D (inference).

Caveats (documented, same for every cell — comparisons remain valid):
  * "bytes accessed" comes from the CPU-backend HLO; TPU fuses more
    aggressively, so the memory term is an upper bound.
  * train collective totals scale by the microbatch count (FSDP gathers
    re-run per microbatch).

Usage: PYTHONPATH=src python -m repro.launch.roofline [--csv out.csv]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s/link

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


def _load(path: Path) -> Optional[dict]:
    if not path.exists():
        return None
    rec = json.loads(path.read_text())
    return rec if rec.get("status") == "ok" else None


def _metrics(rec: dict) -> Dict[str, float]:
    ca = rec["cost_analysis"]
    # XLA:CPU float-normalizes bf16 to f32 before the final HLO, so every
    # byte count for a bf16 config is ~2x the TPU value; corrected here.
    # (f32 optimizer moments are touched once per step — second-order.)
    corr = 0.5 if rec.get("dtype") == "bfloat16" else 1.0
    out = {"flops": ca.get("flops", 0.0),
           # TPU fusion-aware HBM model when available; raw CPU-HLO bytes
           # (which count unfused elementwise chains, ~20x high) otherwise
           "bytes": corr * float(rec.get("hbm_bytes_est")
                                 or ca.get("bytes accessed", 0.0)),
           "bytes_raw": ca.get("bytes accessed", 0.0),
           "coll": corr * float(rec["collectives"]["total_bytes"])}
    for c in _COLL:
        out[f"coll_{c}"] = corr * float(rec["collectives"][c]["bytes"])
    return out


def _rwkv_recurrence_flops(cfg, shape_kind: str, global_batch: int,
                           seq_len: int, dp_shards: int) -> float:
    """Analytic WKV-recurrence add-on (the time scan is a while loop even in
    the probes).  ~8 flops per (head, hd, hd) element per token."""
    if cfg.block != "rwkv" or shape_kind == "decode":
        return 0.0
    H = cfg.d_model // cfg.head_dim
    per_token = 8.0 * H * cfg.head_dim * cfg.head_dim
    tokens_dev = global_batch * seq_len / dp_shards
    mult = 3.0 if shape_kind == "train" else 1.0
    return per_token * tokens_dev * cfg.n_layers * mult


def analyze_cell(arch: str, shape: str, probe_suffixes=None,
                 out_dir: Path = OUT_DIR) -> Optional[dict]:
    from repro.configs.registry import get_config
    from repro.launch.dryrun import probe_pair

    main = _load(out_dir / f"{arch}__{shape}__single.json")
    if main is None:
        return None
    l1, l2 = probe_pair(arch) if probe_suffixes is None else probe_suffixes
    p1 = _load(out_dir / f"{arch}__{shape}__single__probe{l1}.json")
    p2 = _load(out_dir / f"{arch}__{shape}__single__probe{l2}.json")
    p1m = _load(out_dir / f"{arch}__{shape}__single__probe{l1}mb2.json")
    p2m = _load(out_dir / f"{arch}__{shape}__single__probe{l2}mb2.json")
    cfg = get_config(arch, "full")
    devices = main["devices"]
    kind = main["kind"]
    mb = main["microbatch"] or (max(1, main["global_batch"] // 32)
                                if kind == "train" else 1)

    if p1 is not None and p2 is not None:
        m1, m2 = _metrics(p1), _metrics(p2)
        n_scanned = cfg.n_layers - cfg.n_dense_prefix

        def extrapolate(v1, v2):
            body = v2 - v1
            return max((v1 - body) + body * n_scanned, 0.0)

        totals = {k: extrapolate(m1[k], m2[k]) for k in m1}
        if kind == "train" and p1m is not None and p2m is not None:
            # separate param collectives (x mb in production: FSDP gathers /
            # grad reductions per microbatch) from activation collectives
            # (total invariant to the microbatch split):
            #   coll(L, MB) = act(L) + MB * par(L)
            m1m, m2m = _metrics(p1m), _metrics(p2m)
            for k in list(totals):
                if not k.startswith("coll"):
                    continue
                par1, par2 = m1m[k] - m1[k], m2m[k] - m2[k]
                act1, act2 = m1[k] - par1, m2[k] - par2
                par_tot = extrapolate(par1, par2)
                act_tot = extrapolate(act1, act2)
                totals[k] = act_tot + mb * par_tot
            method = f"probe-delta(L={l1},{l2};mb-split)"
        elif kind == "train":
            totals["coll"] *= mb
            for c in _COLL:
                totals[f"coll_{c}"] *= mb
            method = f"probe-delta(L={l1},{l2};coll*mb UPPER BOUND)"
        else:
            method = f"probe-delta(L={l1},{l2})"
    else:
        totals = _metrics(main)
        method = "raw-hlo (UNDERCOUNTS scan bodies)"

    # analytic recurrence add-on (rwkv)
    dp = devices // 16 if "model" in ("model",) else devices
    dp_shards = max(devices // 16, 1)   # single-pod: data axis = 16
    totals["flops"] += _rwkv_recurrence_flops(
        cfg, kind, main["global_batch"], main["seq_len"], dp_shards)

    tokens = main["global_batch"] * (main["seq_len"] if kind != "decode" else 1)
    n = cfg.param_count()
    n_active = cfg.active_param_count()
    model_flops = (6.0 * n_active * tokens if kind == "train"
                   else 2.0 * n_active * tokens)
    model_flops_dev = model_flops / devices

    compute_s = totals["flops"] / PEAK_FLOPS
    memory_s = totals["bytes"] / HBM_BW
    coll_s = totals["coll"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]
    model_time = model_flops_dev / PEAK_FLOPS
    return {
        "arch": arch, "shape": shape, "kind": kind, "devices": devices,
        "method": method,
        "flops_dev": totals["flops"], "bytes_dev": totals["bytes"],
        "coll_dev": totals["coll"],
        "coll_breakdown": {c: totals[f"coll_{c}"] for c in _COLL},
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": model_flops, "model_flops_dev": model_flops_dev,
        "useful_ratio": model_flops_dev / max(totals["flops"], 1.0),
        "roofline_fraction": model_time / max(bound_s, 1e-12),
        "memory_analysis": main.get("memory_analysis", {}),
    }


def fix_note(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.5:
            return ("compute-bound with <50% useful FLOPs: cut remat "
                    "recompute (save attn outputs) or offload")
        return "compute-bound near peak: increase arithmetic efficiency via fusion"
    if d == "memory":
        if row["kind"] == "decode":
            return ("memory-bound on KV/weight streaming: quantize cache, "
                    "grow batch, or fuse decode matmuls")
        return ("memory-bound: fuse elementwise chains, widen per-op tiles, "
                "avoid re-materialized activations")
    return ("collective-bound: overlap FSDP gathers with layer compute, "
            "reduce-scatter grads, or shrink TP degree")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default=str(OUT_DIR.parent / "roofline.csv"))
    ap.add_argument("--markdown", default=str(OUT_DIR.parent / "roofline.md"))
    args = ap.parse_args()

    from repro.configs.registry import list_archs
    from repro.configs.shapes import SHAPES

    rows = []
    for arch in list_archs():
        for shape in SHAPES:
            row = analyze_cell(arch, shape)
            if row:
                rows.append(row)

    import csv as _csv
    with open(args.csv, "w", newline="") as f:
        w = _csv.writer(f)
        w.writerow(["arch", "shape", "kind", "method", "flops_dev",
                    "bytes_dev", "coll_dev", "compute_s", "memory_s",
                    "collective_s", "dominant", "model_flops_dev",
                    "useful_ratio", "roofline_fraction"])
        for r in rows:
            w.writerow([r["arch"], r["shape"], r["kind"], r["method"],
                        f"{r['flops_dev']:.4g}", f"{r['bytes_dev']:.4g}",
                        f"{r['coll_dev']:.4g}", f"{r['compute_s']:.4g}",
                        f"{r['memory_s']:.4g}", f"{r['collective_s']:.4g}",
                        r["dominant"], f"{r['model_flops_dev']:.4g}",
                        f"{r['useful_ratio']:.3f}",
                        f"{r['roofline_fraction']:.3f}"])

    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful FLOP ratio | roofline frac | fix |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {fix_note(r)} |")
    Path(args.markdown).write_text("\n".join(lines) + "\n")
    print("\n".join(lines))
    print(f"\nwrote {args.csv} and {args.markdown} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
