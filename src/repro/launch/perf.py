"""Perf-iteration driver for the §Perf hillclimb.

Lowers ONE (arch x shape) cell under a named variant of tuning knobs, runs
the two cost probes, and prints the reconstructed roofline terms — the
measure step of the hypothesis -> change -> measure loop.  Results append to
experiments/perf/<arch>__<shape>__<variant>.json so EXPERIMENTS.md §Perf can
table them.

  python -m repro.launch.perf --arch llama3-405b --shape decode_32k \
      --variant baseline
  python -m repro.launch.perf --arch qwen3-moe-235b-a22b --shape train_4k \
      --variant mb4 --microbatch 4
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import json
import sys
import traceback
from pathlib import Path

PERF_DIR = Path(__file__).resolve().parents[3] / "experiments" / "perf"


def run_variant(arch: str, shape: str, variant: str, knobs: dict) -> dict:
    from repro.launch.dryrun import lower_cell, probe_pair
    from repro.launch.roofline import (PEAK_FLOPS, HBM_BW, ICI_BW,
                                       _metrics, _rwkv_recurrence_flops)
    from repro.configs.registry import get_config

    cfg = get_config(arch, "full")
    l1, l2 = probe_pair(arch)
    probe_knobs = dict(knobs)
    mb_knob = probe_knobs.pop("microbatch", 0)
    recs = {}
    from repro.configs.shapes import SHAPES
    points = [(l1, 1), (l2, 1)]
    if SHAPES[shape].kind == "train":
        points += [(l1, 2), (l2, 2)]
    for pl, pmb in points:
        recs[(pl, pmb)] = lower_cell(arch, shape, "single", "full",
                                     probe_layers=pl, microbatch=pmb,
                                     **probe_knobs)
        assert recs[(pl, pmb)]["status"] == "ok", recs[(pl, pmb)]
    m1, m2 = _metrics(recs[(l1, 1)]), _metrics(recs[(l2, 1)])
    n_scanned = cfg.n_layers - cfg.n_dense_prefix

    def extrapolate(v1, v2):
        body = v2 - v1
        return max((v1 - body) + body * n_scanned, 0.0)

    totals = {k: extrapolate(m1[k], m2[k]) for k in m1}
    kind = recs[(l1, 1)]["kind"]
    mb_prod = mb_knob or (
        max(1, recs[(l1, 1)]["global_batch"] // 32) if kind == "train" else 1)
    if kind == "train":
        m1m, m2m = _metrics(recs[(l1, 2)]), _metrics(recs[(l2, 2)])
        for k in list(totals):
            if not k.startswith("coll"):
                continue
            par1, par2 = m1m[k] - m1[k], m2m[k] - m2[k]
            act1, act2 = m1[k] - par1, m2[k] - par2
            totals[k] = extrapolate(act1, act2) + mb_prod * extrapolate(par1,
                                                                        par2)
    totals["flops"] += _rwkv_recurrence_flops(
        cfg, kind, recs[(l1, 1)]["global_batch"], recs[(l1, 1)]["seq_len"],
        max(recs[(l1, 1)]["devices"] // 16, 1))
    tokens = recs[(l1, 1)]["global_batch"] * (
        recs[(l1, 1)]["seq_len"] if kind != "decode" else 1)
    model_flops_dev = ((6.0 if kind == "train" else 2.0)
                       * cfg.active_param_count() * tokens
                       / recs[(l1, 1)]["devices"])
    terms = {"compute_s": totals["flops"] / PEAK_FLOPS,
             "memory_s": totals["bytes"] / HBM_BW,
             "collective_s": totals["coll"] / ICI_BW}
    dominant = max(terms, key=terms.get)
    out = {
        "arch": arch, "shape": shape, "variant": variant, "knobs": knobs,
        "flops_dev": totals["flops"], "bytes_dev": totals["bytes"],
        "coll_dev": totals["coll"], **terms,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "model_flops_dev": model_flops_dev,
        "useful_ratio": model_flops_dev / max(totals["flops"], 1.0),
        "roofline_fraction": (model_flops_dev / PEAK_FLOPS)
        / max(terms[dominant], 1e-12),
        "coll_breakdown": {k[5:]: v for k, v in totals.items()
                           if k.startswith("coll_")},
        "memory_analysis_probe": recs[(l2, 1)].get("memory_analysis", {}),
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--remat", default="block",
                    choices=["block", "dots", "names", "none"])
    ap.add_argument("--attn-mode", default=None,
                    choices=[None, "kv_heads", "q_groups", "kv_seq"])
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel residual stream (Megatron SP)")
    args = ap.parse_args()

    knobs = {"remat": args.remat}
    if args.microbatch:
        knobs["microbatch"] = args.microbatch
    if args.attn_mode:
        knobs["attn_mode"] = args.attn_mode
    if args.sp:
        knobs["act_overrides"] = {"act_seq": ("model",)}
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    try:
        rec = run_variant(args.arch, args.shape, args.variant, knobs)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape,
               "variant": args.variant, "status": "error",
               "traceback": traceback.format_exc()}
    out = PERF_DIR / f"{args.arch}__{args.shape}__{args.variant}.json"
    out.write_text(json.dumps(rec, indent=1))
    show = {k: rec.get(k) for k in ("variant", "compute_s", "memory_s",
                                    "collective_s", "dominant",
                                    "roofline_fraction", "useful_ratio")}
    print(json.dumps(show, indent=1))
    if "traceback" in rec:
        print(rec["traceback"][-1500:], file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
