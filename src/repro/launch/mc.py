"""CLI for the chip-ensemble Monte Carlo engine (`repro.mc`).

Two network levels:

  --network layer (default): a population of sampled chip instances of ONE
  IRC layer, Table-II-style mean±std bit-agreement columns (the mAP-drop
  proxy), plus quantiles and throughput.

  --network detector: WHOLE-network MC — a chip population of the IRC
  detector (`DetectorEnsemble`), metric = mAP@0.5 per chip on a synthetic
  IVS-geometry eval batch, i.e. Table II in the paper's own units.  Weights
  are random-init unless `--det-steps` runs a short QAT first, so absolute
  mAP is only meaningful with training; drops and spreads are reported the
  same way either way.

Every run gets an `experiments/<run_id>/` directory (root set by
`--run-dir`; empty string disables) holding `manifest.json` (args, git SHA,
jax versions, host, backend), the `metrics.jsonl` event stream (per-chunk
per-chip values + convergence stderr), per-chip metric vectors as `.npy`,
the machine-readable `results.csv` (or wherever `--out` points), and — with
`--trace` — a `jax.profiler` trace.  stdout carries the human-readable
summary only.

  # 64-chip ensemble, all nonideal effects, proposed design
  PYTHONPATH=src python -m repro.launch.mc --chips 64

  # full Table II ablation sweep, baseline binary mapping, kernel backend
  PYTHONPATH=src python -m repro.launch.mc --chips 128 --scheme binary \
      --bias-rows 0 --ablation table2 --backend kernel

  # per-die bias calibration + JSON report + machine CSV
  PYTHONPATH=src python -m repro.launch.mc --chips 64 --calibrate \
      --json experiments/mc_proposed.json --out mc_proposed.csv

  # adaptive population size: stop when the mean is known to ±0.002
  PYTHONPATH=src python -m repro.launch.mc --chips 1024 \
      --stderr-target 0.002

  # whole-detector population mAP, smoke geometry, 16 chips, with trace
  PYTHONPATH=src python -m repro.launch.mc --network detector --chips 16 \
      --det-steps 100 --ablation table2 --trace

  # detector sweep with the Pallas chip-batched kernel forced onto every
  # group matmul (auto consults src/repro/kernels/tuning.json instead)
  PYTHONPATH=src python -m repro.launch.mc --network detector --chips 4 \
      --chunk 2 --det-backend kernel

  # ensemble-aware QAT: single-draw vs 4-chip-population training, scored
  # side by side with whole-network population mAP
  PYTHONPATH=src python -m repro.launch.mc --network detector --chips 16 \
      --det-steps 100 --train-chips 4

  # aging timeline: measured device backend swept over deployment ages —
  # every ablation column repeats per age ("mAP after N days" curves)
  PYTHONPATH=src python -m repro.launch.mc --network detector --chips 16 \
      --device-model measured --t-days 0,30,365
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path


def build_layer(args):
    import jax
    import jax.numpy as jnp
    from repro.core import (ternary_quantize, binary_quantize, ternary_planes,
                            binary_planes, ideal_ternary_matmul)

    k_w, k_x = jax.random.split(jax.random.PRNGKey(args.seed))
    w_lat = jax.random.normal(k_w, (args.fan_in, args.n_out))
    if args.scheme == "ternary":
        w = ternary_quantize(w_lat)
        mapped = ternary_planes(w, bias_rows=args.bias_rows)
    else:
        w = binary_quantize(w_lat)
        mapped = binary_planes(w)
    x = (jax.random.uniform(k_x, (args.batch, args.fan_in))
         > 1.0 - args.density).astype(jnp.float32)
    ref_bits = (ideal_ternary_matmul(x, w) > 0).astype(jnp.float32)
    return mapped, x, ref_bits


def _parse_t_days(text):
    """--t-days "0,30,365" -> [0.0, 30.0, 365.0] (one age per sweep pass)."""
    try:
        ts = [float(t) for t in str(text).split(",") if t.strip() != ""]
    except ValueError:
        raise SystemExit(f"--t-days must be a comma list of numbers, "
                         f"got {text!r}")
    if not ts:
        raise SystemExit("--t-days needs at least one age")
    if any(t < 0 for t in ts):
        raise SystemExit("--t-days ages must be >= 0")
    return ts


def _age_label(name, t, ts):
    """Column label with the age suffixed when sweeping multiple ages."""
    return name if len(ts) == 1 else f"{name}@t{t:g}d"


def _ablation_columns(args, table):
    """Resolve --ablation into named columns; the ideal column always runs
    (drop_vs_ideal is measured against the simulated ideal, never 1.0)."""
    if args.ablation == "table2":
        return list(table)
    by_name = dict(table)
    if args.ablation not in by_name:
        raise SystemExit(f"unknown ablation column: {args.ablation!r} "
                         f"(choices: table2, {', '.join(by_name)})")
    columns = [("ideal", by_name["ideal"])]
    if args.ablation != "ideal":
        columns.append((args.ablation, by_name[args.ablation]))
    return columns


def _make_runlog(args):
    """RunLog under `<run-dir>/<run_id>/` (NullRunLog when --run-dir '')."""
    from repro.obs import maybe_runlog
    obs = maybe_runlog(bool(args.run_dir), f"mc-{args.network}",
                       args=vars(args), root=args.run_dir,
                       run_id=args.run_id or None)
    if obs.path is not None:
        print(f"# run dir: {obs.path}")
    if args.trace:
        obs.start_trace()
    return obs


def _write_csv(args, obs, lines) -> None:
    """Machine-readable CSV through the obs writer: `--out PATH` wins, else
    `<run_dir>/results.csv`; stdout stays human-readable either way."""
    text = "\n".join(lines) + "\n"
    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(text)
    else:
        out = obs.write_text("results.csv", text)
    if out is not None:
        print(f"# wrote {out}")


def _write_report(args, obs, report) -> None:
    obs.write_text("report.json", json.dumps(report, indent=1))
    if not args.json:
        return
    out = Path(args.json)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=1))
    print(f"# wrote {out}")


def _train_checkpoints(args, det, data):
    """QAT checkpoint(s) to sweep: the legacy single path, or — with
    --train-chips N — a single-draw vs N-chip-ensemble QAT pair trained from
    the SAME root key with the surrogate-noise config on, so the population
    sweep isolates what the chips axis buys (paper Sec. V)."""
    import jax
    from repro.core import NonidealConfig
    if args.train_chips <= 1:
        if args.det_steps:
            from repro.train.det_qat import quick_qat
            return {"qat": quick_qat(det, data, args.det_steps,
                                     args.det_batch, seed=args.seed)}
        return {"init": det.init(jax.random.PRNGKey(args.seed))}
    if not args.det_steps:
        raise SystemExit("--train-chips needs --det-steps > 0 "
                         "(it compares QAT'd checkpoints)")
    from repro.train.det_qat import quick_qat
    noise = NonidealConfig.all()   # surrogate models devvar + SA of this set
    root = jax.random.PRNGKey(args.seed + 1)
    common = dict(seed=args.seed, key=root, cfg_ni=noise)
    return {
        "single": quick_qat(det, data, args.det_steps, args.det_batch,
                            train_chips=1, **common),
        f"ens{args.train_chips}": quick_qat(
            det, data, args.det_steps, args.det_batch,
            train_chips=args.train_chips,
            resample_every=args.resample_every, **common),
    }


def run_detector(args) -> None:
    """Whole-network MC: population mAP@0.5 of the smoke-geometry detector."""
    import jax
    from repro.configs import yolo_irc
    from repro.data.detection import SyntheticDetectionData
    from repro.device import get_device_model
    from repro.models import IRCDetector
    from repro.mc import McConfig, run_mc_detector, TABLE2_ABLATION
    from repro.obs import PhaseTimer

    obs = _make_runlog(args)
    cfg = yolo_irc.smoke(args.det_scheme)
    det = IRCDetector(cfg)
    data = SyntheticDetectionData(img_hw=cfg.img_hw, stride=cfg.strides,
                                  n_classes=cfg.n_classes,
                                  n_anchors=cfg.n_anchors)
    qat_timer = PhaseTimer("qat", unit="checkpoints")
    with qat_timer.lap() as lap:
        checkpoints = _train_checkpoints(args, det, data)
        lap.items = len(checkpoints)
    qat_timer.log_to(obs, det_steps=args.det_steps,
                     train_chips=args.train_chips)
    # deployment calibration: stem running stats (+ baseline block BN)
    calib = data.batch_for_step(999, args.det_batch * 4)
    ev = data.batch_for_step(1000, args.det_batch)

    mc = McConfig(n_chips=args.chips, chunk_size=args.chunk)
    key = jax.random.PRNGKey(args.seed)
    columns = _ablation_columns(args, TABLE2_ABLATION)
    ts = _parse_t_days(args.t_days)
    # auto defers to the committed kernels/tuning.json; kernel forces the
    # Pallas chip-batched path (interpret mode on CPU)
    use_kernel = {"auto": None, "jnp": False, "kernel": True}[args.det_backend]

    print(f"# detector {args.det_scheme} {cfg.img_hw[0]}x{cfg.img_hw[1]} "
          f"batch={args.det_batch} chips={args.chips} "
          f"qat_steps={args.det_steps} train_chips={args.train_chips} "
          f"backend={args.det_backend} "
          f"pipeline={not args.no_pipeline} "
          f"device={args.device_model} t_days={','.join(f'{t:g}' for t in ts)}")
    print(f"{'checkpoint':10s} {'config':14s} {'map50 mean±std':>16s} "
          f"{'drop':>7s} {'q05':>7s} {'q50':>7s} {'q95':>7s} "
          f"{'chips':>5s} {'chips/s':>8s} {'compile_s':>9s}")
    csv_lines = ["checkpoint,config,map50_mean,map50_std,drop_vs_ideal,"
                 "q05,q50,q95,chips,chips_per_s,compile_s,"
                 "device_model,t_days"]
    report = {"args": vars(args), "run_id": obs.manifest.get("run_id"),
              "results": {}}
    for ck, params in checkpoints.items():
        params = det.calibrate_bn(params, calib.images)
        report["results"][ck] = {}
        for t in ts:
            device = get_device_model(args.device_model, t_days=t)
            results = {}
            for name, cfg_ni in columns:
                obs.log_event("ablation_column", checkpoint=ck, column=name,
                              device_model=args.device_model, t_days=t)
                results[name] = run_mc_detector(
                    key, det, params, ev.images, ev.boxes, ev.classes,
                    mc=dataclasses.replace(mc, cfg=cfg_ni, device=device),
                    obs=obs, stderr_target=args.stderr_target,
                    pipeline=not args.no_pipeline, use_kernel=use_kernel)
            # the drop is measured against the SAME age's simulated ideal
            ideal_mean = results["ideal"].metrics["map50"]["mean"]
            for name, res in results.items():
                label = _age_label(name, t, ts)
                m = res.metrics["map50"]
                drop = ideal_mean - m["mean"]
                print(f"{ck:10s} {label:14s} "
                      f"{m['mean']:8.4f}±{m['std']:6.4f} {drop:7.4f} "
                      f"{m.get('q05', float('nan')):7.4f} "
                      f"{m.get('q50', float('nan')):7.4f} "
                      f"{m.get('q95', float('nan')):7.4f} "
                      f"{res.n_chips:5d} {res.chips_per_sec:8.2f} "
                      f"{res.compile_s:9.2f}")
                csv_lines.append(
                    f"{ck},{label},{m['mean']:.6f},{m['std']:.6f},"
                    f"{drop:.6f},"
                    f"{m.get('q05', float('nan')):.6f},"
                    f"{m.get('q50', float('nan')):.6f},"
                    f"{m.get('q95', float('nan')):.6f},{res.n_chips},"
                    f"{res.chips_per_sec:.2f},{res.compile_s:.4f},"
                    f"{args.device_model},{t:g}")
                obs.save_array(f"per_chip_map50_{ck}_{label}",
                               res.per_chip["map50"])
                report["results"][ck][label] = {
                    "metrics": res.metrics, "wall_s": res.wall_s,
                    "compile_s": res.compile_s,
                    "chips_per_sec": res.chips_per_sec,
                    "device_s": res.device_s, "host_s": res.host_s,
                    "device_model": args.device_model, "t_days": t,
                    "per_chip_map50": res.per_chip["map50"].tolist()}
    _write_csv(args, obs, csv_lines)
    _write_report(args, obs, report)
    obs.finalize(status="ok", network="detector",
                 device_model=args.device_model, t_days=ts)


def run_layer(args) -> None:
    import jax
    from repro.device import get_device_model
    from repro.mc import McConfig, run_mc, TABLE2_ABLATION

    obs = _make_runlog(args)
    mapped, x, ref_bits = build_layer(args)
    mc = McConfig(n_chips=args.chips, chunk_size=args.chunk,
                  accumulation=args.accumulation, backend=args.backend,
                  calibrate=args.calibrate)
    key = jax.random.PRNGKey(args.seed)
    ts = _parse_t_days(args.t_days)
    columns = _ablation_columns(args, TABLE2_ABLATION)

    print(f"# {args.scheme} {args.fan_in}x{args.n_out} batch={args.batch} "
          f"chips={args.chips} backend={args.backend} "
          f"device={args.device_model} t_days={','.join(f'{t:g}' for t in ts)}"
          + (" calibrated" if args.calibrate else ""))
    print(f"{'config':14s} {'agree mean±std':>16s} {'drop':>7s} "
          f"{'q05':>7s} {'q50':>7s} {'q95':>7s} {'chips':>5s} "
          f"{'chips/s':>8s} {'compile_s':>9s}")
    csv_lines = ["config,agree_mean,agree_std,drop_vs_ideal,q05,q50,q95,"
                 "chips,chips_per_s,compile_s,device_model,t_days"]
    report = {"args": vars(args), "run_id": obs.manifest.get("run_id"),
              "results": {}}
    for t in ts:
        device = get_device_model(args.device_model, t_days=t)
        results = {}
        for name, cfg in columns:
            obs.log_event("ablation_column", column=name,
                          device_model=args.device_model, t_days=t)
            results[name] = run_mc(
                key, mapped, x, ref_bits=ref_bits,
                mc=dataclasses.replace(mc, cfg=cfg, device=device), obs=obs,
                stderr_target=args.stderr_target)
        # the drop is measured against the SAME age's simulated ideal
        ideal_mean = results["ideal"].metrics["bit_agreement"]["mean"]
        for name, res in results.items():
            label = _age_label(name, t, ts)
            m = res.metrics["bit_agreement"]
            drop = ideal_mean - m["mean"]
            print(f"{label:14s} {m['mean']:8.4f}±{m['std']:6.4f} {drop:7.4f} "
                  f"{m.get('q05', float('nan')):7.4f} "
                  f"{m.get('q50', float('nan')):7.4f} "
                  f"{m.get('q95', float('nan')):7.4f} "
                  f"{res.n_chips:5d} {res.chips_per_sec:8.2f} "
                  f"{res.compile_s:9.2f}")
            csv_lines.append(
                f"{label},{m['mean']:.6f},{m['std']:.6f},{drop:.6f},"
                f"{m.get('q05', float('nan')):.6f},"
                f"{m.get('q50', float('nan')):.6f},"
                f"{m.get('q95', float('nan')):.6f},{res.n_chips},"
                f"{res.chips_per_sec:.2f},{res.compile_s:.4f},"
                f"{args.device_model},{t:g}")
            for metric in ("bit_agreement", "ones_fraction"):
                obs.save_array(f"per_chip_{metric}_{label}",
                               res.per_chip[metric])
            report["results"][label] = {
                "metrics": res.metrics, "wall_s": res.wall_s,
                "compile_s": res.compile_s,
                "chips_per_sec": res.chips_per_sec,
                "device_model": args.device_model, "t_days": t,
                "per_chip_bit_agreement":
                    res.per_chip["bit_agreement"].tolist(),
                "bias_units": (res.bias_units.tolist()
                               if res.bias_units is not None else None)}
    _write_csv(args, obs, csv_lines)
    _write_report(args, obs, report)
    obs.finalize(status="ok", network="layer",
                 device_model=args.device_model, t_days=ts)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="chip-ensemble Monte Carlo sweep (repro.mc)")
    ap.add_argument("--network", default="layer",
                    choices=["layer", "detector"],
                    help="layer: one IRC layer, bit-agreement proxy; "
                         "detector: whole-network mAP@0.5 population sweep")
    ap.add_argument("--det-scheme", default="ternary",
                    choices=["ternary", "binary"],
                    help="detector design (proposed ternary | baseline binary)")
    ap.add_argument("--det-batch", type=int, default=2,
                    help="detector eval batch size")
    ap.add_argument("--det-steps", type=int, default=0,
                    help="short QAT before the detector sweep (0 = random init)")
    ap.add_argument("--train-chips", type=int, default=1,
                    help="ensemble-aware QAT: train a second checkpoint "
                         "against N-chip populations (surrogate noise on) and "
                         "report population mAP for single-draw vs ensemble "
                         "QAT side by side (needs --det-steps)")
    ap.add_argument("--resample-every", type=int, default=1,
                    help="QAT steps between chip-population resamples")
    ap.add_argument("--det-backend", default="auto",
                    choices=["auto", "jnp", "kernel"],
                    help="detector crossbar matmul routing: auto consults "
                         "the committed kernels/tuning.json, jnp forces the "
                         "reference ensemble path, kernel forces the Pallas "
                         "chip-batched kernel (interpret mode on CPU)")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="serial chunk loop (eager ensemble build + blocking "
                         "forward) instead of the double-buffered pipeline")
    ap.add_argument("--chips", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--fan-in", type=int, default=540)
    ap.add_argument("--n-out", type=int, default=60)
    ap.add_argument("--density", type=float, default=0.5,
                    help="activated word-line fraction")
    ap.add_argument("--scheme", default="ternary",
                    choices=["ternary", "binary"])
    ap.add_argument("--bias-rows", type=int, default=32)
    ap.add_argument("--accumulation", default="single_shot",
                    choices=["single_shot", "partial_sum"])
    ap.add_argument("--backend", default="jnp", choices=["jnp", "kernel"])
    ap.add_argument("--ablation", default="all",
                    help="'table2' for the full effect sweep, or one column "
                         "name (ideal|devvar|devvar+nl|devvar+nl+peri|all)")
    ap.add_argument("--device-model", default="analytic",
                    choices=["analytic", "measured"],
                    help="repro.device backend chips are sampled from: "
                         "analytic (the paper's closed forms, default) or "
                         "measured (the packaged tabulated dataset)")
    ap.add_argument("--t-days", default="0",
                    help="comma list of deployment ages in days; each age "
                         "wraps the backend in a RetentionDrift timeline and "
                         "repeats the sweep (0 = programming day; e.g. "
                         "'0,30,365' for an aging curve)")
    ap.add_argument("--calibrate", action="store_true",
                    help="per-die extra-bias calibration before evaluation")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="", help="write the report here")
    ap.add_argument("--run-dir", default="experiments",
                    help="root for the experiments/<run_id>/ run directory "
                         "(manifest + metrics.jsonl + per-chip .npy; "
                         "'' disables)")
    ap.add_argument("--run-id", default="",
                    help="explicit run id (default: timestamped)")
    ap.add_argument("--out", default="",
                    help="machine-readable CSV path "
                         "(default <run_dir>/results.csv)")
    ap.add_argument("--stderr-target", type=float, default=None,
                    help="stop each sweep once the standard error of the "
                         "mean reaches this target (adaptive chip count)")
    ap.add_argument("--trace", action="store_true",
                    help="capture a jax.profiler trace into the run dir")
    args = ap.parse_args()

    if args.network == "detector":
        # layer-only knobs have no detector equivalent: fail loudly rather
        # than emit a report whose vars(args) provenance silently lies
        layer_only = ("scheme", "fan_in", "n_out", "density", "bias_rows",
                      "accumulation", "backend", "calibrate", "batch")
        misused = [f"--{n.replace('_', '-')}" for n in layer_only
                   if getattr(args, n) != ap.get_default(n)]
        if misused:
            raise SystemExit(
                f"--network detector does not take {', '.join(misused)} "
                f"(layer-path flags; use --det-scheme/--det-batch/"
                f"--det-steps)")
        run_detector(args)
        return

    det_only = ("train_chips", "resample_every", "det_backend", "no_pipeline")
    misused = [f"--{n.replace('_', '-')}" for n in det_only
               if getattr(args, n) != ap.get_default(n)]
    if misused:
        raise SystemExit(f"--network layer does not take {', '.join(misused)} "
                         f"(detector QAT flags)")
    run_layer(args)


if __name__ == "__main__":
    main()
