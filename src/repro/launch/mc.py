"""CLI for the chip-ensemble Monte Carlo engine (`repro.mc`).

Evaluates a population of sampled chip instances of one IRC layer and prints
Table-II-style mean±std bit-agreement columns (the mAP-drop proxy used across
the benchmark suite), plus quantiles and throughput.

  # 64-chip ensemble, all nonideal effects, proposed design
  PYTHONPATH=src python -m repro.launch.mc --chips 64

  # full Table II ablation sweep, baseline binary mapping, kernel backend
  PYTHONPATH=src python -m repro.launch.mc --chips 128 --scheme binary \
      --bias-rows 0 --ablation table2 --backend kernel

  # per-die bias calibration + JSON report
  PYTHONPATH=src python -m repro.launch.mc --chips 64 --calibrate \
      --json experiments/mc_proposed.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path


def build_layer(args):
    import jax
    import jax.numpy as jnp
    from repro.core import (ternary_quantize, binary_quantize, ternary_planes,
                            binary_planes, ideal_ternary_matmul)

    k_w, k_x = jax.random.split(jax.random.PRNGKey(args.seed))
    w_lat = jax.random.normal(k_w, (args.fan_in, args.n_out))
    if args.scheme == "ternary":
        w = ternary_quantize(w_lat)
        mapped = ternary_planes(w, bias_rows=args.bias_rows)
    else:
        w = binary_quantize(w_lat)
        mapped = binary_planes(w)
    x = (jax.random.uniform(k_x, (args.batch, args.fan_in))
         > 1.0 - args.density).astype(jnp.float32)
    ref_bits = (ideal_ternary_matmul(x, w) > 0).astype(jnp.float32)
    return mapped, x, ref_bits


def main() -> None:
    ap = argparse.ArgumentParser(
        description="chip-ensemble Monte Carlo sweep (repro.mc)")
    ap.add_argument("--chips", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--fan-in", type=int, default=540)
    ap.add_argument("--n-out", type=int, default=60)
    ap.add_argument("--density", type=float, default=0.5,
                    help="activated word-line fraction")
    ap.add_argument("--scheme", default="ternary",
                    choices=["ternary", "binary"])
    ap.add_argument("--bias-rows", type=int, default=32)
    ap.add_argument("--accumulation", default="single_shot",
                    choices=["single_shot", "partial_sum"])
    ap.add_argument("--backend", default="jnp", choices=["jnp", "kernel"])
    ap.add_argument("--ablation", default="all",
                    help="'table2' for the full effect sweep, or one column "
                         "name (ideal|devvar|devvar+nl|devvar+nl+peri|all)")
    ap.add_argument("--calibrate", action="store_true",
                    help="per-die extra-bias calibration before evaluation")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="", help="write the report here")
    args = ap.parse_args()

    import jax
    from repro.mc import McConfig, run_mc, run_ablation, TABLE2_ABLATION

    mapped, x, ref_bits = build_layer(args)
    mc = McConfig(n_chips=args.chips, chunk_size=args.chunk,
                  accumulation=args.accumulation, backend=args.backend,
                  calibrate=args.calibrate)
    key = jax.random.PRNGKey(args.seed)

    if args.ablation == "table2":
        results = run_ablation(key, mapped, x, ref_bits=ref_bits, mc=mc)
    else:
        by_name = dict(TABLE2_ABLATION)
        if args.ablation not in by_name:
            raise SystemExit(f"unknown ablation column: {args.ablation!r} "
                             f"(choices: table2, {', '.join(by_name)})")
        # the ideal column always runs too: drop_vs_ideal must be measured
        # against the simulated ideal (hrs_leak + tie-breaking keep its
        # agreement below 1), never against a literal 1.0
        columns = [("ideal", by_name["ideal"])]
        if args.ablation != "ideal":
            columns.append((args.ablation, by_name[args.ablation]))
        results = {name: run_mc(key, mapped, x, ref_bits=ref_bits,
                                mc=dataclasses.replace(mc, cfg=cfg))
                   for name, cfg in columns}

    ideal_mean = results["ideal"].metrics["bit_agreement"]["mean"]
    print(f"# {args.scheme} {args.fan_in}x{args.n_out} batch={args.batch} "
          f"chips={args.chips} backend={args.backend}"
          + (" calibrated" if args.calibrate else ""))
    print("config,agree_mean,agree_std,drop_vs_ideal,q05,q50,q95,chips_per_s")
    report = {"args": vars(args), "results": {}}
    for name, res in results.items():
        m = res.metrics["bit_agreement"]
        drop = ideal_mean - m["mean"]
        print(f"{name},{m['mean']:.4f},{m['std']:.4f},{drop:.4f},"
              f"{m.get('q05', float('nan')):.4f},"
              f"{m.get('q50', float('nan')):.4f},"
              f"{m.get('q95', float('nan')):.4f},{res.chips_per_sec:.2f}")
        report["results"][name] = {
            "metrics": res.metrics, "wall_s": res.wall_s,
            "chips_per_sec": res.chips_per_sec,
            "per_chip_bit_agreement":
                res.per_chip["bit_agreement"].tolist(),
            "bias_units": (res.bias_units.tolist()
                           if res.bias_units is not None else None)}
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, indent=1))
        print(f"# wrote {out}")


if __name__ == "__main__":
    main()
