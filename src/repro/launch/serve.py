"""Serving launcher: batched request serving for both network families.

`--network lm` (default) restores (or inits) a language model and serves
batched prompts with the slot-wave `ServeEngine` — the decode step is the
exact function the dry-run's `decode_*` cells lower for the production
meshes.  `--network detector` builds the IRC detector and serves a batch of
synthetic images through the population-aware `DetectorServeEngine`: every
request is answered by a chip committee with mean/std/quantile confidence
(runbook: docs/serving.md).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
      --requests 8 --slots 4 --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --network detector \
      --requests 6 --slots 2 --committee 4 --run-dir experiments
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs.registry import get_config, list_archs


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--network", default="lm", choices=["lm", "detector"])
    # LM engine
    ap.add_argument("--arch", default="phi3-medium-14b", choices=list_archs())
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a training checkpoint")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    # detector engine
    ap.add_argument("--det-scheme", default="ternary",
                    choices=["ternary", "binary"],
                    help="[detector] weight mapping scheme")
    ap.add_argument("--committee", type=int, default=4,
                    help="[detector] chips answering each request")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="[detector] admission-control queue bound")
    ap.add_argument("--det-backend", default="auto",
                    choices=["auto", "jnp", "kernel"],
                    help="[detector] grouped-matmul backend routing")
    # shared
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--run-dir", default="",
                    help="experiments/<run_id>/ run directory root "
                         "(per-wave telemetry; '' disables)")
    ap.add_argument("--run-id", default="")
    ap.add_argument("--trace", action="store_true",
                    help="capture a jax.profiler trace into the run dir")
    return ap


def _check_flag_use(ap: argparse.ArgumentParser,
                    args: argparse.Namespace) -> None:
    """Fail fast on flags that silently do nothing for the chosen network."""
    lm_only = ["arch", "variant", "ckpt_dir", "max_new", "max_len",
               "temperature"]
    det_only = ["det_scheme", "committee", "max_queue", "det_backend"]
    misused = lm_only if args.network == "detector" else det_only
    for n in misused:
        if getattr(args, n) != ap.get_default(n):
            ap.error(f"--{n.replace('_', '-')} only applies to "
                     f"--network {'lm' if n in lm_only else 'detector'}")


def _serve_lm(args, obs) -> None:
    from repro.models import LM
    from repro.serve import ServeEngine

    cfg = get_config(args.arch, args.variant)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        template = jax.eval_shape(lambda: params)
        try:  # params-only checkpoint
            restored, step = mgr.restore_latest(template)
        except KeyError:  # training checkpoint: TrainState paths (params/...)
            restored, step = mgr.restore_latest({"params": template})
            restored = restored["params"] if restored else None
        if restored is not None:
            params = restored
            print(f"restored step {step} from {args.ckpt_dir}")

    engine = ServeEngine(lm, params, batch_slots=args.slots,
                         max_len=args.max_len, seed=args.seed,
                         temperature=args.temperature, obs=obs)
    rng = jax.random.PRNGKey(1)
    prompts = []
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        n = 2 + i % 6
        prompts.append([int(t) for t in
                        jax.random.randint(k, (n,), 0, cfg.vocab_size)])
    t0 = time.time()
    results = engine.generate(prompts, max_new_tokens=args.max_new)
    dt = time.time() - t0
    new = sum(len(r.tokens) for r in results)
    for i, r in enumerate(results[:4]):
        print(f"req {i}: {len(r.prompt)} prompt toks -> {r.tokens[:8]}...")
    decode = engine.stats()["decode"]
    print(f"{len(results)} requests, {new} new tokens, {dt:.1f}s "
          f"({new/dt:.1f} tok/s overall; "
          f"{decode['tokens_per_sec']:.1f} tok/s steady decode, "
          f"compile {decode['compile_s']:.1f}s)")
    engine.log_stats()
    obs.finalize(status="ok", requests=len(results), new_tokens=new,
                 decode_tokens_per_sec=decode["tokens_per_sec"])


def _serve_detector(args, obs) -> None:
    from repro.configs import yolo_irc
    from repro.data.detection import SyntheticDetectionData
    from repro.models.detector import IRCDetector
    from repro.serve import DetectorServeEngine

    cfg = yolo_irc.smoke(args.det_scheme)
    det = IRCDetector(cfg)
    params = det.init(jax.random.PRNGKey(0))
    data = SyntheticDetectionData(cfg.img_hw, cfg.n_classes, cfg.n_anchors,
                                  cfg.strides, seed=1)
    calib = data.batch_for_step(0, max(args.requests, 2))
    params = det.calibrate_bn(params, calib.images)

    # auto defers to the committed kernels/tuning.json; kernel forces the
    # Pallas chip-batched path (interpret mode on CPU)
    use_kernel = {"auto": None, "jnp": False, "kernel": True}[args.det_backend]
    engine = DetectorServeEngine(
        det, params, committee=args.committee, batch_slots=args.slots,
        max_queue=args.max_queue, seed=args.seed, use_kernel=use_kernel,
        obs=obs)

    images = np.asarray(calib.images)
    engine.start()
    t0 = time.time()
    rids = [engine.submit(images[i % images.shape[0]])
            for i in range(args.requests)]
    responses = [engine.result(rid, timeout=600) for rid in rids]
    dt = time.time() - t0
    engine.stop()

    for r in responses[:4]:
        c = r.confidence
        print(f"req {r.request_id} (wave {r.wave}): "
              f"{len(r.detections)} boxes, confidence "
              f"{c['mean']:.3f}±{c['std']:.3f} "
              f"[q05={c.get('q05', 0.0):.3f}, q95={c.get('q95', 0.0):.3f}], "
              f"queue {r.queue_s*1e3:.0f}ms")
    stats = engine.stats()
    lat = stats["queue_latency"]
    print(f"{len(responses)} requests over {args.committee}-chip committees, "
          f"{dt:.1f}s ({len(responses)/dt:.2f} req/s overall; "
          f"{stats['wave']['requests_per_sec']:.2f} req/s steady, "
          f"compile {stats['wave']['compile_s']:.1f}s; "
          f"queue p50={lat['p50']*1e3:.0f}ms p95={lat['p95']*1e3:.0f}ms)")
    engine.log_stats()
    obs.finalize(status="ok", requests=len(responses),
                 committee=args.committee,
                 requests_per_sec=stats["wave"]["requests_per_sec"],
                 queue_p50_s=lat["p50"], queue_p95_s=lat["p95"])


def main():
    """CLI entry: parse flags, open the run dir, route to the engine."""
    ap = _build_parser()
    args = ap.parse_args()
    _check_flag_use(ap, args)

    from repro.obs import maybe_runlog
    name = ("serve-detector" if args.network == "detector"
            else f"serve-{args.arch}")
    obs = maybe_runlog(bool(args.run_dir), name, args=vars(args),
                       root=args.run_dir, run_id=args.run_id or None)
    if obs.path is not None:
        print(f"# run dir: {obs.path}")
    if args.trace:
        obs.start_trace()

    if args.network == "detector":
        _serve_detector(args, obs)
    else:
        _serve_lm(args, obs)


if __name__ == "__main__":
    main()
