"""Serving launcher: restore (or init) a model and serve batched requests
with the slot-wave engine.  The decode step is the exact function the
dry-run's `decode_*` cells lower for the production meshes.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
      --requests 8 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.ckpt import CheckpointManager
from repro.configs.registry import get_config, list_archs
from repro.models import LM
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b", choices=list_archs())
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from a training checkpoint")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--run-dir", default="",
                    help="experiments/<run_id>/ run directory root "
                         "(per-wave telemetry; '' disables)")
    ap.add_argument("--run-id", default="")
    ap.add_argument("--trace", action="store_true",
                    help="capture a jax.profiler trace into the run dir")
    args = ap.parse_args()

    from repro.obs import maybe_runlog
    obs = maybe_runlog(bool(args.run_dir), f"serve-{args.arch}",
                       args=vars(args), root=args.run_dir,
                       run_id=args.run_id or None)
    if obs.path is not None:
        print(f"# run dir: {obs.path}")
    if args.trace:
        obs.start_trace()

    cfg = get_config(args.arch, args.variant)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        template = jax.eval_shape(lambda: params)
        try:  # params-only checkpoint
            restored, step = mgr.restore_latest(template)
        except KeyError:  # training checkpoint: TrainState paths (params/...)
            restored, step = mgr.restore_latest({"params": template})
            restored = restored["params"] if restored else None
        if restored is not None:
            params = restored
            print(f"restored step {step} from {args.ckpt_dir}")

    engine = ServeEngine(lm, params, batch_slots=args.slots,
                         max_len=args.max_len,
                         temperature=args.temperature, obs=obs)
    rng = jax.random.PRNGKey(1)
    prompts = []
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        n = 2 + i % 6
        prompts.append([int(t) for t in
                        jax.random.randint(k, (n,), 0, cfg.vocab_size)])
    t0 = time.time()
    results = engine.generate(prompts, max_new_tokens=args.max_new)
    dt = time.time() - t0
    new = sum(len(r.tokens) for r in results)
    for i, r in enumerate(results[:4]):
        print(f"req {i}: {len(r.prompt)} prompt toks -> {r.tokens[:8]}...")
    decode = engine.stats()["decode"]
    print(f"{len(results)} requests, {new} new tokens, {dt:.1f}s "
          f"({new/dt:.1f} tok/s overall; "
          f"{decode['tokens_per_sec']:.1f} tok/s steady decode, "
          f"compile {decode['compile_s']:.1f}s)")
    engine.log_stats()
    obs.finalize(status="ok", requests=len(results), new_tokens=new,
                 decode_tokens_per_sec=decode["tokens_per_sec"])


if __name__ == "__main__":
    main()
