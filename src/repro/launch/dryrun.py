"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective analysis.

MUST be the process entry point (``python -m repro.launch.dryrun``): the
XLA device-count override below precedes ANY jax import.  Smoke tests and
benchmarks import repro normally and see the host's single device.

Usage:
  python -m repro.launch.dryrun                    # all cells, both meshes
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --mesh single
  python -m repro.launch.dryrun --list             # enumerate cells
Results: one JSON per cell under experiments/dryrun/.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512")

# ---- nothing above this line may import jax ----
import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config, list_archs
from repro.configs.shapes import SHAPES, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models.transformer import LM
from repro.sharding.rules import spec_for_axes, tree_pspecs, cache_axes_tree
from repro.train.steps import (make_train_step, abstract_train_state,
                               train_state_axes)

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# ops that materialize HBM tensors on TPU (elementwise chains — convert /
# broadcast / add / mul / select / exp ... — fuse into their consumers, so
# the CPU backend's per-op "bytes accessed" overstates TPU traffic ~20x;
# measured on llama3-405b: 2.1 TB of `convert` outputs alone)
_MATERIALIZING = {"dot", "convolution", "gather", "scatter",
                  "dynamic-update-slice", "dynamic-slice", "sort",
                  "fusion", "copy", "transpose", "reduce", "rng",
                  "all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute",
                  "all-gather-start", "all-reduce-start"}

# `%name = <type(s)> <opname>(` — opname taken at the op position only
# (metadata strings like op_name="...transpose(jvp..." must not match)
_OP_RE = re.compile(r" = ((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)) "
                    r"([a-z][a-z0-9-]*)\(")


def hbm_bytes_estimate(hlo_text: str) -> float:
    """TPU HBM-traffic model: 2x (write+read) the output bytes of every
    materializing op; fusable elementwise ops are free (they fuse).
    Ops INSIDE fusion/reduction sub-computations are skipped (the fusion's
    own output already accounts for the materialization); entry parameters
    are accounted separately via memory_analysis.argument_size."""
    total = 0
    skipping = False
    for line in hlo_text.splitlines():
        ls = line.rstrip()
        if ls.endswith("{") and ("fused_computation" in ls or
                                 "region_" in ls or
                                 ls.lstrip().startswith("%wrapped")):
            skipping = True
            continue
        if skipping:
            if ls.strip() == "}":
                skipping = False
            continue
        m = _OP_RE.search(line)
        if m and m.group(2) in _MATERIALIZING:
            total += _shape_bytes(m.group(1))
    return 2.0 * total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in post-SPMD HLO.

    HLO line: ``%x = bf16[8,128]{1,0} all-gather(...)`` (possibly tuple
    results).  `-start` variants (async) are counted; `-done` are not
    (same op, avoids double counting).
    """
    out = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for c in _COLLECTIVES:
            marker = f" {c}("
            start_marker = f" {c}-start("
            if marker in line or start_marker in line:
                lhs = line.split(f"{c}(")[0].split(f"{c}-start(")[0]
                lhs = lhs.split(" = ")[-1] if " = " in lhs else lhs
                out[c]["count"] += 1
                out[c]["bytes"] += _shape_bytes(lhs)
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes",
                 "host_argument_size_in_bytes",
                 "host_output_size_in_bytes", "host_temp_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def _cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float))}


def _batch_shardings(batch_abs, mesh):
    return jax.tree.map(
        lambda sds: NamedSharding(
            mesh, spec_for_axes(("act_batch",) + (None,) * (len(sds.shape) - 1),
                                sds.shape, mesh)),
        batch_abs)


def _ns_tree(pspec_tree, mesh):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, mesh_kind: str, variant: str,
               *, microbatch: int = 0, remat: str = "block",
               probe_layers: int = 0, attn_mode: str | None = None,
               act_overrides: dict | None = None,
               extra: dict | None = None) -> dict:
    """Lower + compile one cell; returns the record dict.

    probe_layers > 0 lowers a COST PROBE: the same architecture truncated
    to that many layers with the layer loop UNROLLED and microbatch=1, so
    cost_analysis counts every layer (XLA counts while-loop bodies once).
    The roofline harness reconstructs full-depth totals from the deltas of
    two probes (see repro.launch.roofline).
    """
    import dataclasses as _dc
    cfg = get_config(arch, variant)
    scan_layers = True
    if probe_layers:
        cfg = _dc.replace(cfg, n_layers=probe_layers)
        scan_layers = False
        # cost probes run at microbatch=1 unless the caller probes the
        # microbatch scaling itself (param-collective separation)
        microbatch = microbatch or 1
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    lm = LM(cfg).use_mesh(mesh, act_overrides=act_overrides)
    if attn_mode is not None:
        lm.attn_mode = attn_mode
    specs = input_specs(lm, shape)
    param_axes = lm.logical_axes()
    param_abs = lm.abstract_params()
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            mb = microbatch or max(1, shape.global_batch // 32)
            state_abs = abstract_train_state(lm)
            state_shardings = _ns_tree(
                tree_pspecs(train_state_axes(lm), state_abs, mesh), mesh)
            batch_abs = specs["batch"]
            batch_sh = _batch_shardings(batch_abs, mesh)
            step_fn = make_train_step(lm, remat=remat, microbatch=mb,
                                      scan_layers=scan_layers,
                                      scan_microbatches=not probe_layers)
            jitted = jax.jit(step_fn,
                             in_shardings=(state_shardings, batch_sh),
                             out_shardings=(state_shardings, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            params_sh = _ns_tree(tree_pspecs(param_axes, param_abs, mesh), mesh)
            batch_abs = specs["batch"]
            batch_sh = _batch_shardings(batch_abs, mesh)

            def prefill(params, batch):
                logits, _ = lm.apply(params, batch["tokens"], remat=remat,
                                     scan_layers=scan_layers)
                return logits

            jitted = jax.jit(prefill, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(param_abs, batch_abs)
        else:  # decode
            params_sh = _ns_tree(tree_pspecs(param_axes, param_abs, mesh), mesh)
            tokens_abs, cache_abs = specs["tokens"], specs["cache"]
            cache_sh = _ns_tree(tree_pspecs(cache_axes_tree(cache_abs),
                                            cache_abs, mesh), mesh)
            tok_sh = _batch_shardings(tokens_abs, mesh)

            def serve_step(params, tokens, cache):
                return lm.decode_step(params, tokens, cache,
                                      scan_layers=scan_layers)

            jitted = jax.jit(serve_step,
                             in_shardings=(params_sh, tok_sh, cache_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(param_abs, tokens_abs, cache_abs)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = _memory_analysis_dict(compiled)
    # op traffic + one read of the live inputs (params/optimizer/caches)
    hbm_est = hbm_bytes_estimate(hlo) + mem.get("argument_size_in_bytes", 0)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "status": "ok",
        "devices": n_dev, "microbatch": microbatch, "remat": remat,
        "probe_layers": probe_layers,
        "n_layers": cfg.n_layers, "n_dense_prefix": cfg.n_dense_prefix,
        "global_batch": shape.global_batch, "seq_len": shape.seq_len,
        "kind": shape.kind, "block": cfg.block, "dtype": cfg.dtype,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem,
        "cost_analysis": _cost_analysis_dict(compiled),
        "hbm_bytes_est": hbm_est,
        "collectives": coll,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "hlo_lines": len(hlo.splitlines()),
    }
    if extra:
        rec.update(extra)
    return rec


def run_cell_subprocess(arch, shape, mesh_kind, variant, out_path: Path,
                        timeout=3600) -> bool:
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh_kind, "--variant", variant,
           "--out", str(out_path)]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[2])
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=timeout)
    if r.returncode != 0:
        err = {"arch": arch, "shape": shape, "mesh": mesh_kind,
               "status": "error", "stderr": r.stderr[-4000:]}
        out_path.write_text(json.dumps(err, indent=1))
        return False
    return True


def all_cells(meshes=("single", "multi")):
    for arch in list_archs():
        for shape in SHAPES:
            for mesh_kind in meshes:
                yield arch, shape, mesh_kind


def probe_pair(arch: str):
    """(L1, L2) probe depths: MoE dense prefixes stay in the prefix term."""
    cfg = get_config(arch, "full")
    base = cfg.n_dense_prefix + 1
    return base, base + 1


def run_probes(force: bool = False):
    """Cost probes for every runnable single-pod cell (roofline input).

    Train cells get FOUR probes (L1/L2 x mb1/mb2): the mb delta separates
    parameter collectives (FSDP gathers/grad reductions, which re-run per
    microbatch in production) from activation collectives (whose total is
    microbatch-invariant)."""
    failures = 0
    for arch in list_archs():
        l1, l2 = probe_pair(arch)
        # enumerate (probe_layers, microbatch) points
        for shape in SHAPES:
            cfg = get_config(arch, "full")
            if not shape_applicable(cfg, SHAPES[shape])[0]:
                continue
            points = [(l1, 1), (l2, 1)]
            if SHAPES[shape].kind == "train":
                points += [(l1, 2), (l2, 2)]
            for pl, mb in points:
                suffix = f"probe{pl}" + (f"mb{mb}" if mb > 1 else "")
                out = OUT_DIR / f"{arch}__{shape}__single__{suffix}.json"
                if out.exists() and not force:
                    rec = json.loads(out.read_text())
                    if rec.get("status") == "ok":
                        continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", "single",
                       "--probe-layers", str(pl),
                       "--probe-microbatch", str(mb), "--out", str(out)]
                env = dict(os.environ)
                env["PYTHONPATH"] = str(Path(__file__).resolve().parents[2])
                t0 = time.time()
                r = subprocess.run(cmd, env=env, capture_output=True,
                                   text=True, timeout=3600)
                if r.returncode != 0:
                    out.write_text(json.dumps(
                        {"arch": arch, "shape": shape, "mesh": "single",
                         "probe_layers": pl, "status": "error",
                         "stderr": r.stderr[-4000:]}))
                    failures += 1
                    status = "error"
                else:
                    status = json.loads(out.read_text()).get("status")
                print(f"probe {arch:24s} {shape:12s} L={pl} mb={mb} "
                      f"{status:8s} {time.time()-t0:6.1f}s", flush=True)
    print(f"probes done; {failures} failures")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--variant", default="full")
    ap.add_argument("--out")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--probe-layers", type=int, default=0,
                    help="cost probe: truncate to N layers, unroll, mb=1")
    ap.add_argument("--probe-microbatch", type=int, default=0,
                    help="probe microbatch (param-collective separation)")
    ap.add_argument("--probes", action="store_true",
                    help="driver: run the two cost probes for every "
                         "single-pod cell (for the roofline)")
    args = ap.parse_args()

    if args.list:
        for cell in all_cells():
            print(*cell)
        return

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    if args.probes:
        run_probes(force=args.force)
        return
    if args.arch and args.shape:
        # single cell, in-process (the subprocess worker path)
        try:
            rec = lower_cell(args.arch, args.shape, args.mesh, args.variant,
                             probe_layers=args.probe_layers,
                             microbatch=args.probe_microbatch)
        except Exception:
            rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                   "status": "error", "traceback": traceback.format_exc()}
        suffix = (f"__probe{args.probe_layers}"
                  + (f"mb{args.probe_microbatch}"
                     if args.probe_microbatch > 1 else "")
                  ) if args.probe_layers else ""
        out = Path(args.out) if args.out else (
            OUT_DIR / f"{args.arch}__{args.shape}__{args.mesh}{suffix}.json")
        out.write_text(json.dumps(rec, indent=1))
        print(json.dumps({k: rec.get(k) for k in
                          ("arch", "shape", "mesh", "status", "compile_s")}))
        if rec["status"] == "error":
            print(rec.get("traceback", rec.get("reason", ""))[-2000:],
                  file=sys.stderr)
            sys.exit(1)
        return

    # driver mode: every cell in its own subprocess (resumable)
    failures = 0
    for arch, shape, mesh_kind in all_cells():
        out = OUT_DIR / f"{arch}__{shape}__{mesh_kind}.json"
        if out.exists() and not args.force:
            rec = json.loads(out.read_text())
            if rec.get("status") in ("ok", "skipped"):
                continue
        t0 = time.time()
        ok = run_cell_subprocess(arch, shape, mesh_kind, "full", out)
        rec = json.loads(out.read_text())
        status = rec.get("status")
        print(f"{arch:24s} {shape:12s} {mesh_kind:6s} {status:8s} "
              f"{time.time()-t0:7.1f}s", flush=True)
        failures += (status == "error")
    print(f"done; {failures} failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
