"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.  The production target is a TPU v5e pod of
16x16 = 256 chips; the multi-pod configuration stacks 2 pods = 512 chips
with a leading "pod" mesh axis (data-center network between pods, ICI
within a pod).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (tests / examples): 1D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
