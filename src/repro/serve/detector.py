"""Population-aware detector serving: continuous batching over chip
committees.

Serving the IRC detector means answering each request with a calibrated
uncertainty drawn from a committee of sampled virtual dies — not a single
chip's lucky draw.  This engine grows the slot-wave idea of
`repro.serve.engine.ServeEngine` into a detector service:

  submit / result        bounded async request queue with admission control
                         (`ServeQueueFull` once `max_queue` is reached);
                         requests may arrive from any thread
  wave scheduler         pending images batch into waves of `batch_slots`
                         lanes; one wave = ONE jitted dispatch of
                         `repro.mc.committee_wave_forward`, with the next
                         wave dispatched to the device while the host
                         decodes the current one (the PR 6 double-buffer)
  DetectionResponse      boxes decoded from the committee-MEAN prediction
                         plus population mean/std/quantile confidence over
                         the per-chip detection scores

Key discipline (repro.analysis rule KEY004): the engine holds only a root
key; request `rid`'s committee is keyed by the STATELESS coordinate
`fold_in(root, rid)`, never by a split chain threaded through engine state.
A request's committee draws are therefore independent of which requests
preceded it or share its wave, and bit-identical to
`run_mc_detector(fold_in(root, rid), ...)` at the same chip ids — pinned by
tests/test_serve_detector.py.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nonideal as ni
from repro.mc.detector_mc import committee_wave_forward, detector_planes
from repro.mc.stats import StreamingMoments, DEFAULT_QUANTILES
from repro.obs import LatencyTracker, PhaseTimer, RunLog, as_runlog
from repro.train.det_loss import decode_detections

# Short waves pad up to `batch_slots` lanes with this reserved request id so
# every wave runs the ONE compiled executable; `submit` rejects user ids at
# or above it.  Pad lanes are discarded on the host.
PAD_REQUEST_ID = 0x7FFFFFFF


class ServeQueueFull(RuntimeError):
    """Admission control: the bounded request queue is at capacity."""


@dataclasses.dataclass(frozen=True)
class Detection:
    """One decoded box: (cx, cy, w, h) as image fractions, committee-mean
    confidence `score`, and the predicted class index."""
    box: Tuple[float, float, float, float]
    score: float
    cls: int


@dataclasses.dataclass
class DetectionResponse:
    """One request's answer from its chip committee.

    detections  boxes decoded (conf threshold + per-class NMS) from the
                committee-MEAN head prediction
    confidence  population statistics of the per-chip top detection score:
                {count, mean, std, q05..q95} — the committee's calibrated
                uncertainty (std/quantile spread = how much this request's
                answer depends on the die it lands on)
    queue_s     submit -> response wall time (queue wait + wave execution)
    committee   raw per-chip head predictions [chips, gh, gw, ho], kept only
                when the engine was built with `keep_committee=True`
    """
    request_id: int
    detections: List[Detection]
    confidence: Dict[str, float]
    wave: int
    queue_s: float
    committee: Optional[np.ndarray] = None


@dataclasses.dataclass
class _Pending:
    """Queue entry: request payload plus its completion handshake."""
    request_id: int
    image: np.ndarray
    t_submit: float
    done: threading.Event
    response: Optional[DetectionResponse] = None


class DetectorServeEngine:
    """Continuously-batched committee inference over a fixed serving fleet.

    The fleet is the first `committee` chips of the MC key stream; the
    per-layer group planes are hoisted ONCE at construction
    (`detector_planes`), so a wave dispatch carries only images and request
    keys.  Drive it synchronously (`serve_batch`, or `submit` +
    `process_pending` + `result`) or start the background scheduler thread
    (`start`/`stop`) and submit from anywhere.

    `params` should carry calibrated stem-BN running stats
    (`det.calibrate_bn`) — eval-mode normalization uses them.
    """

    def __init__(self, det, params, *, committee: int = 4,
                 batch_slots: int = 4, max_queue: int = 64,
                 cfg_ni: ni.NonidealConfig = ni.NonidealConfig.all(),
                 sa_extra: float = 0.0, seed: int = 0,
                 conf_thresh: float = 0.1, nms_thresh: float = 0.45,
                 quantiles: Tuple[float, ...] = DEFAULT_QUANTILES,
                 use_kernel: Optional[bool] = None,
                 kernel_impl: str = "pallas",
                 keep_committee: bool = False,
                 obs: Optional[RunLog] = None,
                 device=None):
        self.det = det
        self.params = params
        self.committee = committee
        self.slots = batch_slots
        self.max_queue = max_queue
        self.cfg_ni = cfg_ni
        self.sa_extra = sa_extra
        self.conf_thresh = conf_thresh
        self.nms_thresh = nms_thresh
        self.quantiles = quantiles
        self.use_kernel = use_kernel
        self.kernel_impl = kernel_impl
        # repro.device backend the committee chips are sampled from (None:
        # analytic) — e.g. get_device_model("measured", t_days=30) serves
        # the fleet as it will behave after a month in the field
        self.device = device
        self.keep_committee = keep_committee
        # Root key only; request keys are the STABLE coordinates
        # fold_in(root, request_id) — never a split chain through engine
        # state (repro.analysis rule KEY004), so a request's draws cannot
        # depend on serving history.
        self._root_key = jax.random.PRNGKey(seed)
        self._pad_key = jax.random.fold_in(self._root_key, PAD_REQUEST_ID)
        self._chip_ids = jnp.arange(committee, dtype=jnp.uint32)
        self._planes, self._meta = detector_planes(det, params)
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._queue: deque = deque()
        self._pending: Dict[int, _Pending] = {}
        self._next_id = 0
        self._waves = 0
        self._stop_flag = False
        self._thread: Optional[threading.Thread] = None
        self.obs = as_runlog(obs)
        self.wave_timer = PhaseTimer("serve_wave", unit="requests")
        self.dev_timer = PhaseTimer("serve_device", unit="requests")
        self.host_timer = PhaseTimer("serve_host", unit="requests")
        self.queue_latency = LatencyTracker()

    # ------------------------------------------------------------ requests

    def submit(self, image, request_id: Optional[int] = None) -> int:
        """Enqueue one [H, W, 3] image; returns its request id.

        Raises `ServeQueueFull` when `max_queue` requests are already
        waiting (admission control — the caller sheds load or retries), and
        `ValueError` on ids outside [0, PAD_REQUEST_ID).  Thread-safe.
        """
        img = np.asarray(image, np.float32)
        with self._work:
            if len(self._queue) >= self.max_queue:
                raise ServeQueueFull(
                    f"queue at capacity ({self.max_queue} pending)")
            rid = self._next_id if request_id is None else int(request_id)
            if not 0 <= rid < PAD_REQUEST_ID:
                raise ValueError(f"request_id {rid} outside "
                                 f"[0, {PAD_REQUEST_ID})")
            if rid in self._pending:
                raise ValueError(f"request_id {rid} already in flight")
            self._next_id = max(self._next_id, rid + 1)
            p = _Pending(request_id=rid, image=img,
                         t_submit=time.perf_counter(),
                         done=threading.Event())
            self._queue.append(p)
            self._pending[rid] = p
            self._work.notify()
        return rid

    def result(self, request_id: int,
               timeout: Optional[float] = None) -> DetectionResponse:
        """Block until `request_id`'s response is ready and return it."""
        with self._lock:
            p = self._pending[request_id]
        if not p.done.wait(timeout):
            raise TimeoutError(f"request {request_id} not served within "
                               f"{timeout}s")
        with self._lock:
            self._pending.pop(request_id, None)
        assert p.response is not None
        return p.response

    def serve_batch(self, images) -> List[DetectionResponse]:
        """Submit a batch and drain it synchronously; responses in order."""
        rids = [self.submit(img) for img in images]
        self.process_pending()
        return [self.result(rid) for rid in rids]

    # ------------------------------------------------------------ scheduler

    def start(self) -> None:
        """Start the background scheduler thread (continuous batching:
        waves form whenever requests are pending)."""
        if self._thread is not None:
            return
        self._stop_flag = False
        self._thread = threading.Thread(target=self._serve_loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Stop the scheduler thread after it finishes the current wave."""
        with self._work:
            self._stop_flag = True
            self._work.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def process_pending(self) -> int:
        """Drain the queue in the caller's thread; returns requests served.

        Waves are double-buffered like the MC chunk loop: wave k+1 is
        dispatched to the device BEFORE wave k's host-side decode, so the
        device computes the next committee while the host builds responses.
        """
        return self._drain(block=False)

    def _serve_loop(self) -> None:
        while not self._stop_flag:
            self._drain(block=True)

    def _collect_wave(self, block: bool) -> List[_Pending]:
        with self._work:
            while block and not self._queue and not self._stop_flag:
                self._work.wait()
            n = min(self.slots, len(self._queue))
            return [self._queue.popleft() for _ in range(n)]

    def _drain(self, *, block: bool) -> int:
        wave = self._collect_wave(block)
        if not wave:
            return 0
        inflight = None
        served = 0
        while wave:
            with self.wave_timer.lap(items=len(wave)):
                with self.dev_timer.lap(items=len(wave)):
                    # first wave of a drain dispatches inside the lap so the
                    # timers attribute trace/compile to the compile lap
                    if inflight is None:
                        inflight = self._dispatch(wave)
                    preds = np.asarray(jax.block_until_ready(inflight))
                nxt = self._collect_wave(block=False)
                # double buffer: next wave on device DURING host decode
                inflight = self._dispatch(nxt) if nxt else None
                with self.host_timer.lap(items=len(wave)):
                    responses = self._complete(wave, preds)
            self._log_wave(responses)
            served += len(wave)
            wave = nxt
        return served

    # ------------------------------------------------------------ wave body

    def _dispatch(self, wave: List[_Pending]):
        """One wave -> one async device dispatch of the committee forward."""
        n_pad = self.slots - len(wave)
        imgs = [p.image for p in wave] + [np.zeros_like(wave[0].image)] * n_pad
        keys = [jax.random.fold_in(self._root_key, p.request_id)
                for p in wave] + [self._pad_key] * n_pad
        return committee_wave_forward(
            self.params, jnp.asarray(np.stack(imgs)), jnp.stack(keys),
            self._chip_ids, self._planes, det_cfg=self.det.cfg,
            spec=self.det.spec, cfg_ni=self.cfg_ni, sa_extra=self.sa_extra,
            meta=self._meta, use_kernel=self.use_kernel,
            kernel_impl=self.kernel_impl, device=self.device)

    def _complete(self, wave: List[_Pending],
                  preds: np.ndarray) -> List[DetectionResponse]:
        """Decode each live lane's committee into its response."""
        cfg = self.det.cfg
        self._waves += 1
        responses = []
        for i, p in enumerate(wave):
            committee = preds[i]                      # [chips, gh, gw, ho]
            boxes, scores, classes = decode_detections(
                committee.mean(axis=0), cfg.n_anchors, cfg.n_classes,
                self.conf_thresh, self.nms_thresh)
            per_chip = np.array([self._top_score(chip) for chip in committee],
                                np.float32)
            moments = StreamingMoments(self.quantiles)
            moments.update(jnp.asarray(per_chip))
            queue_s = time.perf_counter() - p.t_submit
            p.response = DetectionResponse(
                request_id=p.request_id,
                detections=[Detection(box=tuple(float(v) for v in b),
                                      score=float(s), cls=int(c))
                            for b, s, c in zip(boxes, scores, classes)],
                confidence=moments.summary(), wave=self._waves,
                queue_s=queue_s,
                committee=committee.copy() if self.keep_committee else None)
            self.queue_latency.add(queue_s)
            responses.append(p.response)
            p.done.set()
        return responses

    def _top_score(self, chip_pred: np.ndarray) -> float:
        """One chip's scalar vote: its top decoded detection score (0.0 when
        the chip detects nothing above the confidence threshold)."""
        cfg = self.det.cfg
        _, scores, _ = decode_detections(chip_pred, cfg.n_anchors,
                                         cfg.n_classes, self.conf_thresh,
                                         self.nms_thresh)
        return float(scores[0]) if scores.size else 0.0

    def _log_wave(self, responses: List[DetectionResponse]) -> None:
        self.obs.log_event(
            "serve_wave", wave=self._waves, requests=len(responses),
            committee=self.committee, wall_s=self.wave_timer.last_s,
            device_s=self.dev_timer.last_s, host_s=self.host_timer.last_s,
            queue_s=[r.queue_s for r in responses],
            requests_per_sec=len(responses) / max(self.wave_timer.last_s,
                                                  1e-9))

    # ------------------------------------------------------------ telemetry

    def stats(self) -> Dict[str, Dict[str, float]]:
        """Phase summaries (first-wave compile split from steady-state
        requests/sec) plus queue-latency percentiles."""
        return {"wave": self.wave_timer.summary(),
                "device": self.dev_timer.summary(),
                "host": self.host_timer.summary(),
                "queue_latency": self.queue_latency.summary()}

    def log_stats(self) -> None:
        """Emit the phase/latency summaries as RunLog events."""
        self.wave_timer.log_to(self.obs, waves=self._waves)
        self.dev_timer.log_to(self.obs, waves=self._waves)
        self.host_timer.log_to(self.obs, waves=self._waves)
        self.obs.log_event("serve_latency", **self.queue_latency.summary())
