"""Batched serving engine: fixed-slot batched decode with wave scheduling.

Requests are served in waves of `batch_slots`: each wave shares one batched
KV/state cache, prompts prefill teacher-forced through `decode_step` (so
cache semantics are identical to decode), then all slots decode together one
token per step until EOS/max_new_tokens.  Fixed shapes = one compiled
executable — the form a TPU serving deployment actually runs; the dry-run's
`decode_*` cells lower exactly this step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import LM
from repro.obs import PhaseTimer, RunLog, as_runlog

PyTree = Any


@dataclasses.dataclass
class GenerationResult:
    """One completed LM request: the prompt echoed back, the generated
    token ids, and whether EOS was reached before the token budget."""
    prompt: List[int]
    tokens: List[int]
    finished: bool


class ServeEngine:
    """`obs` (a `repro.obs.RunLog`) streams per-wave telemetry — prefill
    vs decode wall time, new tokens, tokens/sec — and the engine's phase
    timers split the first wave's compile latency from steady-state decode
    throughput (`stats()`)."""

    def __init__(self, lm: LM, params: PyTree, *, batch_slots: int = 4,
                 max_len: int = 128, eos_id: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 obs: Optional[RunLog] = None):
        self.lm = lm
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        # Root key only; sampling keys are derived by STABLE coordinates
        # (wave index, decode step) — never by a split chain threaded
        # through mutable state, which would make a request's draws depend
        # on how many tokens earlier requests happened to generate
        # (repro.analysis rule KEY004).
        self._root_key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(lm.decode_step)
        self.obs = as_runlog(obs)
        self.prefill_timer = PhaseTimer("serve_prefill", unit="tokens")
        self.decode_timer = PhaseTimer("serve_decode", unit="tokens")
        self._waves = 0

    def _sample(self, logits: jax.Array, *, wave: int,
                step: int) -> np.ndarray:
        if self.temperature > 0:
            k = jax.random.fold_in(
                jax.random.fold_in(self._root_key, wave), step)
            return np.asarray(jax.random.categorical(
                k, logits[:, -1, :] / self.temperature), np.int32)
        return np.asarray(jnp.argmax(logits[:, -1, :], -1), np.int32)

    def generate(self, prompts: List[List[int]], max_new_tokens: int = 32
                 ) -> List[GenerationResult]:
        """Serve `prompts` in waves of `self.slots`: batched prefill, then
        step-wise decode until EOS or `max_new_tokens`.  Results come back
        in prompt order regardless of wave composition."""
        results: List[Optional[GenerationResult]] = [None] * len(prompts)
        queue = list(enumerate(prompts))
        while queue:
            wave = queue[:self.slots]
            queue = queue[self.slots:]
            cache = self.lm.init_cache(self.slots, self.max_len)
            maxlen = max(len(p) for _, p in wave)
            assert maxlen + max_new_tokens <= self.max_len, "cache too small"
            toks = np.zeros((self.slots, maxlen), np.int32)
            for s, (_, p) in enumerate(wave):
                toks[s, maxlen - len(p):] = p      # left-pad to align ends
            logits = None
            prompt_toks = sum(len(p) for _, p in wave)
            with self.prefill_timer.lap(items=prompt_toks):
                for t in range(maxlen):           # teacher-forced prefill
                    logits, cache = self._decode(
                        self.params, jnp.asarray(toks[:, t:t + 1]), cache)
                jax.block_until_ready(logits)
            out_tokens: List[List[int]] = [[] for _ in wave]
            finished = [False] * len(wave)
            with self.decode_timer.lap() as lap:
                cur = self._sample(logits, wave=self._waves, step=0)
                for step in range(max_new_tokens):
                    for s in range(len(wave)):
                        if not finished[s]:
                            out_tokens[s].append(int(cur[s]))
                            if (self.eos_id is not None
                                    and cur[s] == self.eos_id):
                                finished[s] = True
                    if all(finished):
                        break
                    logits, cache = self._decode(
                        self.params, jnp.asarray(cur[:, None]), cache)
                    cur = self._sample(logits, wave=self._waves,
                                       step=step + 1)
                lap.items = sum(len(t) for t in out_tokens)
            self._waves += 1
            self.obs.log_event(
                "serve_wave", wave=self._waves, requests=len(wave),
                prompt_tokens=prompt_toks,
                new_tokens=int(lap.items),
                prefill_s=self.prefill_timer.last_s,
                decode_s=self.decode_timer.last_s,
                tokens_per_sec=lap.items / max(self.decode_timer.last_s,
                                               1e-9))
            for s, (req, p) in enumerate(wave):
                results[req] = GenerationResult(prompt=list(p),
                                                tokens=out_tokens[s],
                                                finished=finished[s])
        return [r for r in results if r is not None]

    def stats(self) -> Dict[str, Dict[str, float]]:
        """Phase summaries: first-wave compile latency split from
        steady-state prefill/decode tokens/sec."""
        return {"prefill": self.prefill_timer.summary(),
                "decode": self.decode_timer.summary()}

    def log_stats(self) -> None:
        """Emit the prefill/decode phase summaries to the run log."""
        self.prefill_timer.log_to(self.obs, waves=self._waves)
        self.decode_timer.log_to(self.obs, waves=self._waves)
