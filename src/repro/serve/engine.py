"""Batched serving engine: fixed-slot batched decode with wave scheduling.

Requests are served in waves of `batch_slots`: each wave shares one batched
KV/state cache, prompts prefill teacher-forced through `decode_step` (so
cache semantics are identical to decode), then all slots decode together one
token per step until EOS/max_new_tokens.  Fixed shapes = one compiled
executable — the form a TPU serving deployment actually runs; the dry-run's
`decode_*` cells lower exactly this step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import LM

PyTree = Any


@dataclasses.dataclass
class GenerationResult:
    prompt: List[int]
    tokens: List[int]
    finished: bool


class ServeEngine:
    def __init__(self, lm: LM, params: PyTree, *, batch_slots: int = 4,
                 max_len: int = 128, eos_id: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0):
        self.lm = lm
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(lm.decode_step)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.temperature > 0:
            self.key, k = jax.random.split(self.key)
            return np.asarray(jax.random.categorical(
                k, logits[:, -1, :] / self.temperature), np.int32)
        return np.asarray(jnp.argmax(logits[:, -1, :], -1), np.int32)

    def generate(self, prompts: List[List[int]], max_new_tokens: int = 32
                 ) -> List[GenerationResult]:
        results: List[Optional[GenerationResult]] = [None] * len(prompts)
        queue = list(enumerate(prompts))
        while queue:
            wave = queue[:self.slots]
            queue = queue[self.slots:]
            cache = self.lm.init_cache(self.slots, self.max_len)
            maxlen = max(len(p) for _, p in wave)
            assert maxlen + max_new_tokens <= self.max_len, "cache too small"
            toks = np.zeros((self.slots, maxlen), np.int32)
            for s, (_, p) in enumerate(wave):
                toks[s, maxlen - len(p):] = p      # left-pad to align ends
            logits = None
            for t in range(maxlen):               # teacher-forced prefill
                logits, cache = self._decode(self.params,
                                             jnp.asarray(toks[:, t:t + 1]),
                                             cache)
            out_tokens: List[List[int]] = [[] for _ in wave]
            finished = [False] * len(wave)
            cur = self._sample(logits)
            for _ in range(max_new_tokens):
                for s in range(len(wave)):
                    if not finished[s]:
                        out_tokens[s].append(int(cur[s]))
                        if self.eos_id is not None and cur[s] == self.eos_id:
                            finished[s] = True
                if all(finished):
                    break
                logits, cache = self._decode(self.params,
                                             jnp.asarray(cur[:, None]), cache)
                cur = self._sample(logits)
            for s, (req, p) in enumerate(wave):
                results[req] = GenerationResult(prompt=list(p),
                                                tokens=out_tokens[s],
                                                finished=finished[s])
        return [r for r in results if r is not None]
