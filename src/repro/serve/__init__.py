"""repro.serve — batched serving engines.

  ServeEngine            LM slot-wave engine: fixed-slot batched decode
  DetectorServeEngine    population-aware detector service: async request
                         queue with admission control, continuous wave
                         batching onto `committee_wave_forward`, and
                         per-request committee mean/std/quantile confidence

CLI: `python -m repro.launch.serve` (`--network detector` for the committee
service); runbook: docs/serving.md.
"""
from repro.serve.engine import ServeEngine, GenerationResult
from repro.serve.detector import (DetectorServeEngine, Detection,
                                  DetectionResponse, ServeQueueFull,
                                  PAD_REQUEST_ID)

__all__ = ["ServeEngine", "GenerationResult", "DetectorServeEngine",
           "Detection", "DetectionResponse", "ServeQueueFull",
           "PAD_REQUEST_ID"]
