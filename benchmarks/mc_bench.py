"""Chip-ensemble MC engine throughput: vmapped/jitted (and kernel-backed)
ensemble evaluation vs the pre-`repro.mc` baseline — a Python loop of
single-chip `crossbar_forward` calls, one structural sim per sampled die.

Emits `BENCH_mc.json` at the repo root (chips/sec + wall-clock per path +
speedup, with a "host" section stamping hostname/jax versions/backend so the
machine-relative drift baselines stay interpretable across machines) so the
perf trajectory tracks this path; rows follow the ``name,us_per_call,
derived`` contract of benchmarks/run.py.  Engine throughput is reported as
the compile/steady split (`engine_compile_s` vs steady `engine_chips_per_
sec`) — the old single `wall_s` folded the first-chunk compile into the
rate, which at bench-sized ensembles understated it badly.

Each bench process also writes one `experiments/<run_id>/` run directory
(manifest + per-chunk metrics.jsonl + per-chip .npy) through `repro.obs`.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import (NonidealConfig, ternary_quantize, ternary_planes,
                        ideal_ternary_matmul, crossbar_forward)
from repro.mc import McConfig, run_mc
from repro.obs import PhaseTimer, RunLog, collect_env

Row = Tuple[str, float, str]

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_mc.json"

_OBS = None


def _obs() -> RunLog:
    """One run directory per bench process, shared by every mc_bench
    section (benchmarks.run and check_drift both import this module once)."""
    global _OBS
    if _OBS is None:
        _OBS = RunLog.create("mc_bench")
    return _OBS


def finalize_obs(**summary) -> None:
    """Close the bench run dir if any bench opened one (no-op otherwise)."""
    if _OBS is not None:
        _OBS.finalize(status="ok", **summary)

# bench shapes: one group-conv-sized layer (the paper's detector workload),
# ensemble big enough that per-chunk jit amortizes
N_CHIPS = 64
LOOP_CHIPS = 8          # the baseline loop is timed on a subset (it's slow)
B, FAN_IN, N_OUT = 128, 540, 64


def _layer(seed=0):
    w = ternary_quantize(jax.random.normal(jax.random.PRNGKey(seed),
                                           (FAN_IN, N_OUT)))
    mapped = ternary_planes(w, bias_rows=32)
    x = (jax.random.uniform(jax.random.PRNGKey(seed + 1), (B, FAN_IN))
         > 0.5).astype(jnp.float32)
    ref = (ideal_ternary_matmul(x, w) > 0).astype(jnp.float32)
    return mapped, x, ref


def _loop_chips_per_sec(key, mapped, x, cfg, n_chips) -> float:
    """The old way: one full structural sim per chip, Python-dispatched.
    Median per-chip wall time over the sweep (robust to scheduler noise and
    to how warm the op caches happen to be)."""
    run = lambda c: jax.block_until_ready(crossbar_forward(
        jax.random.fold_in(key, c), x, mapped, cfg=cfg))
    run(0)                               # warm the trace caches
    times = []
    for c in range(n_chips):
        t0 = time.perf_counter()
        run(c)
        times.append(time.perf_counter() - t0)
    return 1.0 / sorted(times)[len(times) // 2]


def mc_engine_bench() -> List[Row]:
    rows: List[Row] = []
    cfg = NonidealConfig.all()
    mapped, x, ref = _layer()
    key = jax.random.PRNGKey(0)

    cps_loop = _loop_chips_per_sec(key, mapped, x, cfg, LOOP_CHIPS)

    record = {"n_chips": N_CHIPS, "batch": B, "fan_in": FAN_IN,
              "n_out": N_OUT, "loop_chips_per_sec": cps_loop}
    mc = McConfig(n_chips=N_CHIPS, chunk_size=16, cfg=cfg)
    # the first run pays the chunked ensemble compile (captured as
    # engine_compile_s); best-of-3 steady reruns give the throughput the
    # streaming engine operates at.  chips_per_sec excludes compile (laps
    # 2..n of the chunk timer), so no separate warmup run is needed.
    first = run_mc(key, mapped, x, ref_bits=ref, mc=mc, obs=_obs())
    res = max((run_mc(key, mapped, x, ref_bits=ref, mc=mc)
               for _ in range(3)), key=lambda r: r.chips_per_sec)
    record["engine_chips_per_sec"] = res.chips_per_sec
    record["engine_compile_s"] = first.compile_s
    record["engine_wall_s"] = res.wall_s
    record["speedup_vs_loop"] = res.chips_per_sec / cps_loop
    m = res.metrics["bit_agreement"]
    record["bit_agreement_mean"] = m["mean"]
    record["bit_agreement_std"] = m["std"]
    _obs().save_array("per_chip_bit_agreement_bench",
                      res.per_chip["bit_agreement"])
    _merge_bench_json(collect_env(), section="host")

    rows.append((f"mc_loop_{LOOP_CHIPS}chips_{B}x{FAN_IN}x{N_OUT}",
                 1e6 / cps_loop, "per_chip;python_loop_crossbar_forward"))
    rows.append((f"mc_engine_{N_CHIPS}chips_{B}x{FAN_IN}x{N_OUT}",
                 1e6 / res.chips_per_sec,
                 f"per_chip;speedup={record['speedup_vs_loop']:.1f}x;"
                 f"agree={m['mean']:.4f}±{m['std']:.4f}"))

    # measured device backend: same sweep, planes drawn through the
    # tabulated inverse-CDF (repro.device).  The ratio vs the analytic run
    # is a machine-independent dispatch-overhead gauge: it collapses if the
    # device seam falls out of the fused chunk jit (e.g. the model stops
    # being a static argument and retriggers per-chunk compilation).
    from repro.device import get_device_model
    mcm = McConfig(n_chips=N_CHIPS, chunk_size=16, cfg=cfg,
                   device=get_device_model("measured"))
    run_mc(key, mapped, x, ref_bits=ref, mc=mcm)
    resm = max((run_mc(key, mapped, x, ref_bits=ref, mc=mcm)
                for _ in range(3)), key=lambda r: r.chips_per_sec)
    record["measured_chips_per_sec"] = resm.chips_per_sec
    record["measured_backend_ratio"] = (resm.chips_per_sec
                                        / res.chips_per_sec)
    rows.append((f"mc_engine_measured_{N_CHIPS}chips_{B}x{FAN_IN}x{N_OUT}",
                 1e6 / resm.chips_per_sec,
                 f"per_chip;device=measured;"
                 f"ratio_vs_analytic={record['measured_backend_ratio']:.2f}"))

    # kernel backend: ONE fused launch per chunk (interpret mode on CPU —
    # wall-clock here characterizes the simulator, not TPU speed)
    mck = McConfig(n_chips=8, chunk_size=8, cfg=cfg, backend="kernel")
    run_mc(key, mapped, x, ref_bits=ref, mc=mck)
    resk = run_mc(key, mapped, x, ref_bits=ref, mc=mck)
    record["kernel_chips_per_sec"] = resk.chips_per_sec
    record["kernel_backend"] = jax.default_backend()
    rows.append((f"mc_engine_kernel_8chips_{B}x{FAN_IN}x{N_OUT}(interp)",
                 1e6 / resk.chips_per_sec, "per_chip;1_launch_per_chunk"))

    _merge_bench_json(record)
    return rows


def _merge_bench_json(record: dict, section: str = "") -> None:
    """Update BENCH_mc.json without clobbering the other benches' sections
    (a named section merges key-by-key: the QAT step-timing bench and the
    population-comparison table both write into "qat")."""
    existing = {}
    if BENCH_JSON.exists():
        try:
            existing = json.loads(BENCH_JSON.read_text())
        except json.JSONDecodeError:
            existing = {}
    if section:
        existing.setdefault(section, {}).update(record)
    else:
        existing.update(record)
    BENCH_JSON.write_text(json.dumps(existing, indent=1))


# detector bench shapes: smoke geometry, small eval batch — the whole-network
# forward is ~100x the single-layer MVM, so fewer chips suffice to time it.
# DET_CHUNK < DET_CHIPS so the chunk stream has steady-state laps and the
# pipelined path has a next chunk to double-buffer.
DET_CHIPS = 8
DET_LOOP_CHIPS = 4
DET_BATCH = 2
DET_CHUNK = 2
DET_KERNEL_CHIPS = 2     # interpret-mode kernel chips (wall-clock bounded)
RSS_REGRESSION_FACTOR = 1.25


def _peak_rss_bytes() -> float:
    import resource
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024.0


def detector_mc_bench() -> List[Row]:
    """Whole-network MC throughput, three ladders on one geometry:

      python loop   one single-chip structural eval per die (pre-PR baseline)
      serial        chunked `run_mc_detector(pipeline=False)` — eager
                    ensemble build, blocking forward, then host mAP
      pipelined     `pipeline=True` — mappings hoisted, sampling fused into
                    the jitted chunk, chunk k+1 on device during chunk k's
                    host-side mAP matching

    plus a kernel-FORCED pipelined run (`use_kernel=True`: the Pallas
    chip-batched kernel on every group matmul — interpret mode on CPU, so
    this times the simulator, not TPU speed; the committed autotuning table
    keeps auto-dispatch off it here).

    Every `run_mc_detector` variant shares the module-level jitted chunk
    programs, which are keyed on the CHUNK shape — the warm-up at a smaller
    ensemble size (`DET_CHIPS // 2`) compiles the one program that every
    later size reuses (`pipeline_compile_s_reused` ~ 0 is the evidence).

    Peak RSS is sampled after the serial and pipelined ladders; the
    double-buffered path holds at most one extra chunk of predictions, so
    the process high-water mark must not grow by more than
    ``RSS_REGRESSION_FACTOR`` over the serial run.
    """
    from repro.configs import yolo_irc
    from repro.data.detection import SyntheticDetectionData
    from repro.models import IRCDetector
    from repro.mc import McConfig, run_mc_detector

    cfg_det = yolo_irc.smoke("ternary")
    det = IRCDetector(cfg_det)
    data = SyntheticDetectionData(img_hw=cfg_det.img_hw,
                                  stride=cfg_det.strides,
                                  n_classes=cfg_det.n_classes,
                                  n_anchors=cfg_det.n_anchors)
    params = det.calibrate_bn(det.init(jax.random.PRNGKey(0)),
                              data.batch_for_step(999, DET_BATCH * 4).images)
    b = data.batch_for_step(1000, DET_BATCH)
    cfg = NonidealConfig.all()
    key = jax.random.PRNGKey(0)

    run = lambda c: jax.block_until_ready(det.apply(
        params, b.images, mode="eval", key=jax.random.fold_in(key, c),
        cfg_ni=cfg))
    run(0)                               # warm the trace caches
    times = []
    for c in range(DET_LOOP_CHIPS):
        t0 = time.perf_counter()
        run(c)
        times.append(time.perf_counter() - t0)
    cps_loop = 1.0 / sorted(times)[len(times) // 2]

    mc = McConfig(n_chips=DET_CHIPS, chunk_size=DET_CHUNK, cfg=cfg)
    sweep = lambda **kw: run_mc_detector(key, det, params, b.images, b.boxes,
                                         b.classes, mc=mc, **kw)

    first = sweep(pipeline=False, obs=_obs())
    res_serial = max((sweep(pipeline=False) for _ in range(2)),
                     key=lambda r: r.chips_per_sec)
    rss_serial = _peak_rss_bytes()

    # warm the fused chunk program at half the ensemble size: the jit cache
    # keys on the CHUNK shape, so the DET_CHIPS runs below reuse it
    warm = run_mc_detector(key, det, params, b.images, b.boxes, b.classes,
                           mc=McConfig(n_chips=DET_CHIPS // 2,
                                       chunk_size=DET_CHUNK, cfg=cfg))
    first_pipe = sweep(pipeline=True)
    res_pipe = max((sweep(pipeline=True) for _ in range(2)),
                   key=lambda r: r.chips_per_sec)
    rss_pipe = _peak_rss_bytes()
    assert rss_pipe <= rss_serial * RSS_REGRESSION_FACTOR, (
        f"pipelined sweep grew peak RSS {rss_pipe / rss_serial:.2f}x over "
        f"the serial run (budget {RSS_REGRESSION_FACTOR}x)")

    import numpy as np
    assert np.array_equal(res_serial.per_chip["map50"],
                          res_pipe.per_chip["map50"]), (
        "pipelined sweep diverged from the serial path")

    # kernel FORCED onto every group matmul (interpret mode on CPU)
    mck = McConfig(n_chips=DET_KERNEL_CHIPS, chunk_size=DET_KERNEL_CHIPS,
                   cfg=cfg)
    run_mc_detector(key, det, params, b.images, b.boxes, b.classes, mc=mck,
                    use_kernel=True)
    res_kern = run_mc_detector(key, det, params, b.images, b.boxes,
                               b.classes, mc=mck, use_kernel=True)

    overlap = lambda r: 1.0 - r.device_s / max(r.wall_s, 1e-9)
    record = {"n_chips": DET_CHIPS, "batch": DET_BATCH,
              "chunk_size": DET_CHUNK,
              "img_hw": list(cfg_det.img_hw),
              "loop_chips_per_sec": cps_loop,
              "engine_chips_per_sec": res_pipe.chips_per_sec,
              "engine_compile_s": first.compile_s,
              "engine_wall_s": res_pipe.wall_s,
              "speedup_vs_loop": res_pipe.chips_per_sec / cps_loop,
              "serial_chips_per_sec": res_serial.chips_per_sec,
              "pipeline_chips_per_sec": res_pipe.chips_per_sec,
              "pipeline_speedup_vs_serial": (res_pipe.chips_per_sec
                                             / res_serial.chips_per_sec),
              "serial_overlap": overlap(res_serial),
              "pipeline_overlap": overlap(res_pipe),
              "pipeline_device_s": res_pipe.device_s,
              "pipeline_host_s": res_pipe.host_s,
              "serial_device_s": res_serial.device_s,
              "serial_host_s": res_serial.host_s,
              "pipeline_compile_s_warm": warm.compile_s,
              "pipeline_compile_s_reused": first_pipe.compile_s,
              "kernel_routed_chips_per_sec": res_kern.chips_per_sec,
              "kernel_routed_chips": DET_KERNEL_CHIPS,
              "kernel_routed_ratio": (res_kern.chips_per_sec
                                      / res_pipe.chips_per_sec),
              "peak_rss_serial_mb": rss_serial / 2**20,
              "peak_rss_pipeline_mb": rss_pipe / 2**20,
              "map50_mean": res_pipe.metrics["map50"]["mean"],
              "map50_std": res_pipe.metrics["map50"]["std"]}
    _obs().save_array("per_chip_map50_bench", res_pipe.per_chip["map50"])
    _merge_bench_json(record, section="detector")
    hw = f"{cfg_det.img_hw[0]}x{cfg_det.img_hw[1]}"
    return [
        (f"mc_det_loop_{DET_LOOP_CHIPS}chips_{hw}", 1e6 / cps_loop,
         "per_chip;python_loop_single_chip_eval"),
        (f"mc_det_serial_{DET_CHIPS}chips_{hw}",
         1e6 / res_serial.chips_per_sec,
         f"per_chip;overlap={record['serial_overlap']:.2f}"),
        (f"mc_det_pipeline_{DET_CHIPS}chips_{hw}",
         1e6 / res_pipe.chips_per_sec,
         f"per_chip;speedup_vs_serial="
         f"{record['pipeline_speedup_vs_serial']:.2f}x;"
         f"overlap={record['pipeline_overlap']:.2f};"
         f"map50={record['map50_mean']:.3f}±{record['map50_std']:.3f}"),
        (f"mc_det_kernel_{DET_KERNEL_CHIPS}chips_{hw}(interp)",
         1e6 / res_kern.chips_per_sec,
         "per_chip;use_kernel=True;pallas_interpret"),
    ]


def autotune_roofline_bench() -> List[Row]:
    """Block-shape sweep of `irc_mvm_chips` on the engine-bench geometry,
    recorded as roofline rows (achieved GFLOP/s per candidate vs the
    reference path).  On CPU the kernel runs in interpret mode — the sweep
    characterizes the simulator and justifies the committed
    `tuning.json` use_kernel=false entries; on TPU the same rows become the
    real roofline.  A reduced candidate set keeps the interpret-mode wall
    bounded; the full sweep is `python -m repro.kernels.autotune --write`.
    """
    from repro.kernels import autotune

    C, M, N, K = 8, B, N_OUT, FAN_IN + 32     # the mc_engine_bench problem
    record_, roof = autotune.autotune_problem(
        C, M, N, K, candidates=((8, 128, 256), (32, 128, 128)))
    committed = autotune.lookup(C, M, N, K) or {}
    _merge_bench_json({"problem": f"c{C}_m{M}_n{N}_k{K}",
                       "backend": jax.default_backend(),
                       "rows": roof,
                       "fresh_winner": record_,
                       "committed": committed},
                      section="autotune_roofline")
    rows: List[Row] = []
    for r in roof:
        tag = ("ref" if r["impl"] == "ref"
               else f"bm{r['bm']}_bn{r['bn']}_bk{r['bk']}")
        rows.append((f"irc_mvm_chips_tune_{tag}_c{C}_{M}x{K}x{N}",
                     r["us"], f"per_call;gflops={r['gflops']:.2f}"))
    return rows


# ensemble-QAT step timing: smoke geometry, small batch — the chips axis is
# folded into the batch, so step time should scale sub-linearly to linearly
# in train_chips (shared-placement count hoisting + one conv for all chips)
QAT_CHIPS = (1, 2, 4)
QAT_BATCH = 4


def qat_step_bench() -> List[Row]:
    """Step time of the detector QAT step vs `train_chips` (the cost knob of
    ensemble-aware QAT).  train_chips=1 is the legacy single-draw step, so
    the chips=1 row doubles as the QAT-throughput drift baseline."""
    from repro.configs import yolo_irc
    from repro.data.detection import SyntheticDetectionData
    from repro.models import IRCDetector
    from repro.optim import adamw_init
    from repro.train.steps import ensemble_key_for_step, make_det_qat_step

    cfg_det = yolo_irc.smoke("ternary")
    det = IRCDetector(cfg_det)
    data = SyntheticDetectionData(img_hw=cfg_det.img_hw,
                                  stride=cfg_det.strides,
                                  n_classes=cfg_det.n_classes,
                                  n_anchors=cfg_det.n_anchors)
    b = data.batch_for_step(0, QAT_BATCH)
    params = det.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    noise = NonidealConfig.all()
    key = jax.random.PRNGKey(1)
    lr = jnp.float32(3e-3)

    rows: List[Row] = []
    hw = f"{cfg_det.img_hw[0]}x{cfg_det.img_hw[1]}"
    record = {"batch": QAT_BATCH, "img_hw": list(cfg_det.img_hw),
              "step_us": {}, "compile_s": {}}
    base_us = None
    for c in QAT_CHIPS:
        step = jax.jit(make_det_qat_step(det, train_chips=c, cfg_ni=noise))
        ek = ensemble_key_for_step(key, 0)
        timer = PhaseTimer(f"qat_step_chips{c}", unit="steps")
        with timer.lap(items=1):                      # compile lap
            jax.block_until_ready(step(params, opt, b.images, b.targets, lr,
                                       key, ek)[0])
        times = []
        for i in range(3):
            with timer.lap(items=1):
                jax.block_until_ready(step(params, opt, b.images, b.targets,
                                           lr, jax.random.fold_in(key, i),
                                           ek)[0])
            times.append(timer.last_s)
        us = sorted(times)[len(times) // 2] * 1e6
        record["step_us"][str(c)] = us
        record["compile_s"][str(c)] = timer.compile_s
        timer.log_to(_obs(), train_chips=c)
        base_us = us if base_us is None else base_us
        rows.append((f"qat_step_chips{c}_{hw}_b{QAT_BATCH}", us,
                     f"per_step;scale_vs_1chip={us / base_us:.2f}x"))
    _merge_bench_json(record, section="qat")
    return rows


# serving bench shapes: smoke geometry, CPU-sized committees.  The wave
# program unrolls `slots` committee lanes, so slots/committee are kept small
# enough that the 3 compiled wave programs stay in the smoke-job budget.
SERVE_COMMITTEES = (1, 2, 4)
SERVE_SLOTS = 2
SERVE_REQUESTS = 6


def serve_bench() -> List[Row]:
    """Detector serving throughput: requests/s vs committee size, plus the
    batching (slots) speedup and submit->response queue-latency percentiles.

    Per committee size: one warm engine pays the wave-program compile, then
    a fresh engine (same module-level jit cache) serves ``SERVE_REQUESTS``
    requests end to end — the timed pass is pure steady-state serving
    (dispatch, double-buffered host decode, response assembly).  The
    drift-gated ratios are machine-relative: ``batch_speedup`` (slots=2 vs
    slots=1 at the same committee) and ``committee_scale_4`` (requests/s at
    committee 4 vs 1 — the cost of 4x the virtual dies per request).
    """
    import numpy as np
    from repro.configs import yolo_irc
    from repro.data.detection import SyntheticDetectionData
    from repro.models import IRCDetector
    from repro.serve import DetectorServeEngine

    cfg_det = yolo_irc.smoke("ternary")
    det = IRCDetector(cfg_det)
    data = SyntheticDetectionData(img_hw=cfg_det.img_hw,
                                  stride=cfg_det.strides,
                                  n_classes=cfg_det.n_classes,
                                  n_anchors=cfg_det.n_anchors)
    params = det.calibrate_bn(det.init(jax.random.PRNGKey(0)),
                              data.batch_for_step(999, 8).images)
    images = np.asarray(data.batch_for_step(1000, SERVE_REQUESTS).images)
    reqs = [images[i] for i in range(SERVE_REQUESTS)]
    hw = f"{cfg_det.img_hw[0]}x{cfg_det.img_hw[1]}"

    def timed_rps(committee: int, slots: int):
        warm = DetectorServeEngine(det, params, committee=committee,
                                   batch_slots=slots)
        warm.serve_batch(reqs[:slots])           # compile the wave program
        compile_s = warm.stats()["wave"]["compile_s"]
        # fresh engine, warm module-level jit cache: the timed pass (and its
        # queue-latency percentiles) is pure steady-state serving
        eng = DetectorServeEngine(det, params, committee=committee,
                                  batch_slots=slots, obs=_obs())
        t0 = time.perf_counter()
        eng.serve_batch(reqs)
        dt = time.perf_counter() - t0
        stats = eng.stats()
        stats["wave"]["compile_s"] = compile_s   # report the real compile
        eng.log_stats()
        return SERVE_REQUESTS / dt, stats

    rows: List[Row] = []
    record = {"slots": SERVE_SLOTS, "requests": SERVE_REQUESTS,
              "img_hw": list(cfg_det.img_hw), "requests_per_sec": {},
              "queue_p50_ms": {}, "queue_p95_ms": {}, "compile_s": {}}
    for c in SERVE_COMMITTEES:
        rps, stats = timed_rps(c, SERVE_SLOTS)
        lat = stats["queue_latency"]
        record["requests_per_sec"][str(c)] = rps
        record["queue_p50_ms"][str(c)] = lat["p50"] * 1e3
        record["queue_p95_ms"][str(c)] = lat["p95"] * 1e3
        record["compile_s"][str(c)] = stats["wave"]["compile_s"]
        rows.append((f"serve_det_c{c}_s{SERVE_SLOTS}_{hw}", 1e6 / rps,
                     f"per_request;committee={c};"
                     f"p50={lat['p50']*1e3:.0f}ms;p95={lat['p95']*1e3:.0f}ms"))

    rps_single, _ = timed_rps(SERVE_COMMITTEES[1], 1)
    rps_batched = record["requests_per_sec"][str(SERVE_COMMITTEES[1])]
    record["single_slot_requests_per_sec"] = rps_single
    record["batch_speedup"] = rps_batched / rps_single
    record["committee_scale_4"] = (record["requests_per_sec"]["4"]
                                   / record["requests_per_sec"]["1"])
    rows.append((f"serve_det_c{SERVE_COMMITTEES[1]}_s1_{hw}",
                 1e6 / rps_single,
                 f"per_request;batch_speedup="
                 f"{record['batch_speedup']:.2f}x"))
    _merge_bench_json(record, section="serve")
    return rows


ALL = [mc_engine_bench, detector_mc_bench, qat_step_bench,
       autotune_roofline_bench, serve_bench]
