"""One benchmark per paper table/figure (JETCAS 2022).

Each function returns rows (name, us_per_call, derived).  The paper-scale
Table II (trained-detector mAP ablation) lives in examples/train_detector.py;
here a bit-error proxy on representative group-conv layers preserves the
paper's orderings minutes-fast, and `table2_detector_map` reports the
population mean±std mAP@0.5 of a briefly-QAT'd smoke detector via the
whole-network MC engine (`repro.mc.run_ablation_detector`).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import (MacroSpec, NonidealConfig,
                        nonlinearity_ratio, sa_required_diff,
                        ternary_quantize, binary_quantize, ternary_planes,
                        binary_planes, crossbar_forward, ideal_ternary_matmul,
                        calibrate_bias, layer_current_stats, wl_point)

Row = Tuple[str, float, str]


def _timeit(fn, n=3) -> float:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def _layer(seed=0, fan_in=540, n_out=60, batch=256, density=0.5,
           scheme="ternary", bias_rows=32):
    w_lat = jax.random.normal(jax.random.PRNGKey(seed), (fan_in, n_out))
    if scheme == "ternary":
        w = ternary_quantize(w_lat)
        mapped = ternary_planes(w, bias_rows=bias_rows)
    else:
        w = binary_quantize(w_lat)
        mapped = binary_planes(w)
    x = (jax.random.uniform(jax.random.PRNGKey(seed + 1),
                            (batch, fan_in)) > 1 - density).astype(jnp.float32)
    return w, mapped, x


def fig7_nonlinearity() -> List[Row]:
    p = jnp.arange(0, 321, dtype=jnp.float32)
    us = _timeit(lambda: nonlinearity_ratio(p))
    r = nonlinearity_ratio(p)
    return [("fig7_nonlinearity_ratio", us,
             f"ratio(p=3)={float(r[3]):.2f};ratio(p=205)={float(r[205]):.3f}")]


def fig9_sa_variation() -> List[Row]:
    p = jnp.arange(0, 321, dtype=jnp.float32)
    us = _timeit(lambda: sa_required_diff(p))
    g = sa_required_diff(p)
    return [("fig9_sa_required_diff", us,
             f"g(0)={float(g[0]):.1f};g(300)={float(g[300]):.1f}units")]


def fig14_wl_voltage() -> List[Row]:
    """WL voltage <-> power <-> accuracy trade-off (power model + bit
    agreement analog of the paper's mAP curve)."""
    rows: List[Row] = []
    w, _, x = _layer()
    ref = ideal_ternary_matmul(x, w) > 0
    for v in (0.40, 0.42, 0.44, 0.46, 0.48):
        spec = MacroSpec(wl_voltage=v)
        mapped = ternary_planes(w, bias_rows=32)
        def run(spec=spec, mapped=mapped):
            return crossbar_forward(jax.random.PRNGKey(2), x, mapped,
                                    cfg=NonidealConfig(device_variation=True),
                                    spec=spec)
        us = _timeit(run, n=1)
        agree = float(jnp.mean((run() > 0.5) == ref))
        i_ua, sigma = wl_point(v)
        energy = spec.read_energy_pj(activated_lrs=0.2 * 1024 * 0.5)
        rows.append((f"fig14_wl_{v:.2f}V", us,
                     f"sigma={sigma:.3f};E={energy:.2f}pJ;agree={agree:.3f}"))
    return rows


def table1_sensing() -> List[Row]:
    """Sensing failures w/o vs w/ calibrated extra bias, for a dense and a
    sparse layer (the paper's per-layer Table I structure)."""
    rows: List[Row] = []
    for name, density in (("dense_layer", 0.5), ("sparse_layer", 0.25)):
        w, mapped0, x = _layer(density=density, bias_rows=0)
        t0 = time.perf_counter()
        ip, ineg, p = layer_current_stats(jax.random.PRNGKey(3), x, mapped0)
        best, report = calibrate_bias(ip, ineg, p)
        us = (time.perf_counter() - t0) * 1e6
        r0, rb = report[0], report[best]
        rows.append((f"table1_{name}", us,
                     f"bias={best};below_lb:{r0['below_lower_bound']:.3f}"
                     f"->{rb['below_lower_bound']:.3f};"
                     f"sa_var:{r0['sensing_variation']:.3f}"
                     f"->{rb['sensing_variation']:.3f}"))
    return rows


# the Table II column set is owned by repro.mc (the CLI and ensemble sweeps
# use the same list); imported mid-file to keep the paper-narrative ordering
from repro.mc import TABLE2_ABLATION as _ABLATION  # noqa: E402


def table2_ablation_proxy() -> List[Row]:
    """Bit-agreement ablation, proposed vs baseline design (Table II
    ordering; full mAP version: examples/train_detector.py)."""
    rows: List[Row] = []
    for design, scheme, acc, bias in (("proposed", "ternary", "single_shot", 32),
                                      ("baseline", "binary", "partial_sum", 0)):
        w, mapped, x = _layer(scheme=scheme, bias_rows=bias)
        ref = ideal_ternary_matmul(x, w) > 0
        vals = []
        for name, cfg in _ABLATION:
            out = crossbar_forward(jax.random.PRNGKey(4), x, mapped, cfg=cfg,
                                   accumulation=acc, partial_rows=212)
            vals.append(f"{name}={float(jnp.mean((out > 0.5) == ref)):.3f}")
        us = _timeit(lambda: crossbar_forward(
            jax.random.PRNGKey(4), x, mapped, cfg=NonidealConfig.all(),
            accumulation=acc, partial_rows=212), n=1)
        rows.append((f"table2_{design}", us, ";".join(vals)))
    return rows


def table2_mc_ensemble() -> List[Row]:
    """Table II as the paper actually states it: mean±std accuracy drop over
    a POPULATION of sampled chips (repro.mc), proposed vs baseline design.
    The single-chip `table2_ablation_proxy` above keeps the orderings; this
    adds the chip-to-chip spread that makes them statistics."""
    import time as _time
    from repro.mc import McConfig, run_ablation

    rows: List[Row] = []
    for design, scheme, acc, bias in (("proposed", "ternary", "single_shot", 32),
                                      ("baseline", "binary", "partial_sum", 0)):
        w, mapped, x = _layer(scheme=scheme, bias_rows=bias)
        ref = (ideal_ternary_matmul(x, w) > 0).astype(jnp.float32)
        mc = McConfig(n_chips=16, chunk_size=16, accumulation=acc,
                      partial_rows=212)
        t0 = _time.perf_counter()
        results = run_ablation(jax.random.PRNGKey(4), mapped, x, ref_bits=ref,
                               mc=mc)
        us = (_time.perf_counter() - t0) * 1e6
        ideal = results["ideal"].metrics["bit_agreement"]["mean"]
        vals = []
        for name, res in results.items():
            m = res.metrics["bit_agreement"]
            vals.append(f"{name}={m['mean']:.3f}±{m['std']:.3f}"
                        f"(drop{ideal - m['mean']:.3f})")
        rows.append((f"table2_mc_{design}", us, ";".join(vals)))
    return rows


def table2_detector_map() -> List[Row]:
    """Table II in the paper's own units: mean±std mAP@0.5 over a chip
    POPULATION of the WHOLE detector (`repro.mc.run_ablation_detector`),
    after a short CPU-sized QAT on the smoke geometry.  The layer-level
    proxies above keep the orderings minutes-fast; this row reports the
    metric the paper actually tabulates (3.85% drop vs. catastrophic)."""
    import time as _time
    from repro.configs import yolo_irc
    from repro.data.detection import SyntheticDetectionData
    from repro.models import IRCDetector
    from repro.train.det_qat import quick_qat
    from repro.mc import McConfig, run_ablation_detector

    rows: List[Row] = []
    for design, scheme in (("proposed", "ternary"), ("baseline", "binary")):
        cfg_det = yolo_irc.smoke(scheme)
        det = IRCDetector(cfg_det)
        data = SyntheticDetectionData(img_hw=cfg_det.img_hw,
                                      stride=cfg_det.strides,
                                      n_classes=cfg_det.n_classes,
                                      n_anchors=cfg_det.n_anchors)
        params = quick_qat(det, data, 40, 4)
        params = det.calibrate_bn(params,
                                  data.batch_for_step(999, 16).images)
        ev = data.batch_for_step(1000, 4)
        t0 = _time.perf_counter()
        results = run_ablation_detector(
            jax.random.PRNGKey(4), det, params, ev.images, ev.boxes,
            ev.classes, mc=McConfig(n_chips=8, chunk_size=8))
        us = (_time.perf_counter() - t0) * 1e6
        ideal = results["ideal"].metrics["map50"]["mean"]
        vals = [f"{name}={res.metrics['map50']['mean']:.3f}"
                f"±{res.metrics['map50']['std']:.3f}"
                f"(drop{ideal - res.metrics['map50']['mean']:.3f})"
                for name, res in results.items()]
        rows.append((f"table2_detector_map_{design}", us, ";".join(vals)))
    return rows


def table2_ensemble_qat() -> List[Row]:
    """Table-II-style population comparison of the QAT surrogates: mean±std
    mAP@0.5 over a chip population for a SINGLE-DRAW-trained vs an
    ENSEMBLE-trained checkpoint (same root key, same surrogate-noise config,
    same step count — the chips axis is the only difference).  Persists the
    numbers into BENCH_mc.json's "qat" section next to the step timings."""
    import time as _time
    import jax.random as jrandom
    from repro.configs import yolo_irc
    from repro.data.detection import SyntheticDetectionData
    from repro.models import IRCDetector
    from repro.train.det_qat import quick_qat
    from repro.mc import McConfig, run_mc_detector
    from benchmarks.mc_bench import _merge_bench_json

    cfg_det = yolo_irc.smoke("ternary")
    det = IRCDetector(cfg_det)
    data = SyntheticDetectionData(img_hw=cfg_det.img_hw,
                                  stride=cfg_det.strides,
                                  n_classes=cfg_det.n_classes,
                                  n_anchors=cfg_det.n_anchors)
    noise = NonidealConfig.all()
    root = jrandom.PRNGKey(1)
    checkpoints = {
        "single": quick_qat(det, data, 40, 4, cfg_ni=noise, key=root),
        "ens4": quick_qat(det, data, 40, 4, cfg_ni=noise, key=root,
                          train_chips=4),
    }
    calib = data.batch_for_step(999, 16).images
    ev = data.batch_for_step(1000, 4)
    mc = McConfig(n_chips=8, chunk_size=8)
    rows: List[Row] = []
    record = {}
    for name, params in checkpoints.items():
        params = det.calibrate_bn(params, calib)
        t0 = _time.perf_counter()
        res = run_mc_detector(jrandom.PRNGKey(4), det, params, ev.images,
                              ev.boxes, ev.classes, mc=mc)
        us = (_time.perf_counter() - t0) * 1e6
        m = res.metrics["map50"]
        record[f"{name}_map50_mean"] = m["mean"]
        record[f"{name}_map50_std"] = m["std"]
        rows.append((f"table2_qat_{name}", us,
                     f"map50={m['mean']:.3f}±{m['std']:.3f};"
                     f"chips={mc.n_chips};qat_steps=40"))
    _merge_bench_json(record, section="qat")
    return rows


def table4_tolerance() -> List[Row]:
    """Tolerance limits: device sigma sweep + SA variation margin sweep."""
    import dataclasses
    rows: List[Row] = []
    w, _, x = _layer()
    ref = ideal_ternary_matmul(x, w) > 0
    mapped = ternary_planes(w, bias_rows=32)
    for sigma in (0.42, 0.43, 0.44, 0.47, 0.52):
        spec = dataclasses.replace(MacroSpec(), sigma_override=sigma)
        out = crossbar_forward(jax.random.PRNGKey(5), x, mapped,
                               cfg=NonidealConfig(device_variation=True),
                               spec=spec)
        agree = float(jnp.mean((out > 0.5) == ref))
        rows.append((f"table4_devstd_{sigma:.2f}", 0.0, f"agree={agree:.3f}"))
    for extra in (0.0, 1.0, 2.0, 3.0):
        out = crossbar_forward(jax.random.PRNGKey(7), x, mapped,
                               cfg=NonidealConfig(sa_variation=True),
                               sa_extra_units=extra)
        agree = float(jnp.mean((out > 0.5) == ref))
        rows.append((f"table4_sa_plus{int(extra)}", 0.0, f"agree={agree:.3f}"))
    return rows


ALL = [fig7_nonlinearity, fig9_sa_variation, fig14_wl_voltage,
       table1_sensing, table2_ablation_proxy, table2_mc_ensemble,
       table2_detector_map, table2_ensemble_qat, table4_tolerance]
