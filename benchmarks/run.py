"""Benchmark harness — one function per paper table/figure plus kernel
benches.  Prints ``name,us_per_call,derived`` CSV (the contract used by
EXPERIMENTS.md).

  PYTHONPATH=src python -m benchmarks.run [--only substr]
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import paper_tables, kernel_bench, mc_bench

    benches = (list(paper_tables.ALL) + list(kernel_bench.ALL)
               + list(mc_bench.ALL))
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{fn.__name__},ERROR,{type(e).__name__}:{e}",
                  file=sys.stderr, flush=True)
    mc_bench.finalize_obs(failures=failures)
    print(f"# total {time.time()-t0:.1f}s, {failures} failures",
          file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
