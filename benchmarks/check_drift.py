"""CI benchmark-drift gate for the MC/QAT pipeline.

Re-runs the smoke-geometry throughput benches (`benchmarks.mc_bench`) and
fails if any tracked metric regresses more than ``DRIFT_FACTOR``x against the
committed ``BENCH_mc.json`` baselines.

Every gated metric is MACHINE-RELATIVE — the ensemble engine's speedup over
the same run's python-loop baseline, and the ensemble-QAT step's scaling
over the same run's single-chip step — so a runner that is merely slower
than the box that committed the baselines does not trip the gate, while the
regressions that matter here do: lost jit caching, an accidental python
loop over chips, per-step retracing of the ensemble step.  The flip side of
ratio gating: a PR that speeds up only the DENOMINATOR leg >2.5x (e.g. a
much faster python-loop `crossbar_forward` or single-chip step) shrinks the
ratio just like a regression would — such a PR should re-run the three
`benchmarks.mc_bench` benches (e.g. via this script) and commit the
refreshed `BENCH_mc.json` baselines alongside the optimization.

Since the obs layer landed, `engine_chips_per_sec` (and hence the gated
speedups) is STEADY-STATE throughput — the first-chunk jit compile is split
out into `engine_compile_s` and reported here informationally, not gated
(compile time is machine- and cache-sensitive).  The baseline's "host"
section (hostname, jax/jaxlib versions, backend) is printed next to the
fresh run's so a drift report is interpretable across machines.

The static-analysis suite's wall time is printed (and gated against its
declared 30s CPU budget, ``ANALYSIS_BUDGET_S``) alongside the throughput
ratios: lint-time checks only stay in the pre-merge loop while they stay
cheap, so their cost is tracked like the perf budgets.

  PYTHONPATH=src python -m benchmarks.check_drift
"""
from __future__ import annotations

import json
import sys

DRIFT_FACTOR = 2.5
ANALYSIS_BUDGET_S = 30.0


def _host_line(record: dict) -> str:
    h = record.get("host", {})
    return (f"{h.get('host', '?')} jax={h.get('jax', '?')} "
            f"jaxlib={h.get('jaxlib', '?')} backend={h.get('backend', '?')}")


def _compile_line(record: dict) -> str:
    det = record.get("detector", {})
    return (f"layer={record.get('engine_compile_s', float('nan')):.2f}s "
            f"detector={det.get('engine_compile_s', float('nan')):.2f}s")


def _metrics(record: dict) -> dict:
    """Machine-relative throughput metrics from a BENCH_mc.json tree.
    Missing sections simply drop out (only keys present in BOTH the
    committed baseline and the fresh run are compared, so adding benches
    never breaks CI)."""
    out = {}
    if "speedup_vs_loop" in record:
        out["layer_engine_speedup_vs_loop"] = record["speedup_vs_loop"]
    if "measured_backend_ratio" in record:
        # measured-device sweep vs analytic, same run — the device-seam
        # dispatch overhead; collapses if backend objects fall out of the
        # jit static args and start recompiling per chunk
        out["layer_measured_backend_ratio"] = record["measured_backend_ratio"]
    det = record.get("detector", {})
    if "speedup_vs_loop" in det:
        out["detector_engine_speedup_vs_loop"] = det["speedup_vs_loop"]
    if "pipeline_speedup_vs_serial" in det:
        # double-buffered chunk stream vs the serial loop, same run — loses
        # its edge if sampling falls out of the fused chunk program or the
        # next-chunk dispatch stops overlapping the host-side mAP matching
        out["detector_pipeline_speedup_vs_serial"] = (
            det["pipeline_speedup_vs_serial"])
    if "pipeline_overlap" in det:
        # fraction of pipelined wall NOT blocked on device (0..1): the
        # realized host/device overlap, a machine characteristic that
        # collapses if double buffering breaks
        out["detector_pipeline_overlap"] = det["pipeline_overlap"]
    if "kernel_routed_ratio" in det:
        # kernel-FORCED detector throughput relative to the same run's
        # pipelined jnp path — tracks the Pallas-routed path's own cost
        # (interpret-mode simulator on CPU) without gating absolute speed
        out["detector_kernel_routed_ratio"] = det["kernel_routed_ratio"]
    step_us = record.get("qat", {}).get("step_us", {})
    if "1" in step_us and "4" in step_us:
        # chips=4 step cost relative to the single-draw step: the ensemble
        # path's own overhead factor, independent of runner speed
        out["qat_step_4chip_scale"] = 1.0 / (step_us["4"] / step_us["1"])
    serve = record.get("serve", {})
    if "batch_speedup" in serve:
        # wave batching (slots=2 vs slots=1, same committee): collapses if
        # the scheduler stops forming multi-request waves or the per-wave
        # dispatch overhead comes back
        out["serve_batch_speedup"] = serve["batch_speedup"]
    if "committee_scale_4" in serve:
        # requests/s at committee 4 relative to committee 1 (same run):
        # the marginal cost of 4x the virtual dies per request — regresses
        # if committee lanes stop sharing the wave program efficiently
        out["serve_committee_scale_4"] = serve["committee_scale_4"]
    return out   # all higher-is-better


def main() -> None:
    from benchmarks import mc_bench

    if not mc_bench.BENCH_JSON.exists():
        print("# no committed BENCH_mc.json baseline; nothing to gate")
        return
    baseline_rec = json.loads(mc_bench.BENCH_JSON.read_text())
    baseline = _metrics(baseline_rec)

    # fresh run (rewrites BENCH_mc.json in the workspace — baseline captured
    # above; CI never commits the rewrite)
    for bench in mc_bench.ALL:
        for name, us, derived in bench():
            print(f"{name},{us:.1f},{derived}", flush=True)
    mc_bench.finalize_obs(mode="check_drift")
    fresh_rec = json.loads(mc_bench.BENCH_JSON.read_text())
    fresh = _metrics(fresh_rec)

    print(f"# host baseline: {_host_line(baseline_rec)}")
    print(f"# host fresh:    {_host_line(fresh_rec)}")
    print(f"# engine compile (info, not gated): "
          f"baseline {_compile_line(baseline_rec)} | "
          f"fresh {_compile_line(fresh_rec)}")

    failures = []

    # lint-time budget: the repro.analysis suite (all three passes over
    # src/) must stay under its declared CPU budget or it falls out of the
    # pre-merge loop
    from repro.analysis import run_all
    _, timing = run_all()
    per_pass = "  ".join(f"{k}={v:.2f}s" for k, v in timing.items()
                         if k != "total")
    verdict = "FAIL" if timing["total"] > ANALYSIS_BUDGET_S else "ok"
    print(f"# analysis_runtime: {timing['total']:.2f}s of "
          f"{ANALYSIS_BUDGET_S:.0f}s budget [{per_pass}] [{verdict}]")
    if timing["total"] > ANALYSIS_BUDGET_S:
        failures.append("analysis_runtime")

    for name in sorted(baseline.keys() & fresh.keys()):
        ratio = baseline[name] / fresh[name]
        verdict = "FAIL" if ratio > DRIFT_FACTOR else "ok"
        print(f"# drift {name}: baseline={baseline[name]:.2f} "
              f"fresh={fresh[name]:.2f} regression={ratio:.2f}x [{verdict}]")
        if ratio > DRIFT_FACTOR:
            failures.append(name)
    for name in sorted(baseline.keys() - fresh.keys()):
        print(f"# drift {name}: skipped (absent from fresh run)")
    if failures:
        print(f"# budget drift (throughput >{DRIFT_FACTOR}x, analysis "
              f">{ANALYSIS_BUDGET_S:.0f}s) on: {', '.join(failures)}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
