"""CI benchmark-drift gate for the MC/QAT pipeline.

Re-runs the smoke-geometry throughput benches (`benchmarks.mc_bench`) and
fails if any tracked metric regresses more than ``DRIFT_FACTOR``x against the
committed ``BENCH_mc.json`` baselines.

Every gated metric is MACHINE-RELATIVE — the ensemble engine's speedup over
the same run's python-loop baseline, and the ensemble-QAT step's scaling
over the same run's single-chip step — so a runner that is merely slower
than the box that committed the baselines does not trip the gate, while the
regressions that matter here do: lost jit caching, an accidental python
loop over chips, per-step retracing of the ensemble step.  The flip side of
ratio gating: a PR that speeds up only the DENOMINATOR leg >2.5x (e.g. a
much faster python-loop `crossbar_forward` or single-chip step) shrinks the
ratio just like a regression would — such a PR should re-run
`benchmarks.run --only mc_` and commit the refreshed `BENCH_mc.json`
baselines alongside the optimization.

  PYTHONPATH=src python -m benchmarks.check_drift
"""
from __future__ import annotations

import json
import sys

DRIFT_FACTOR = 2.5


def _metrics(record: dict) -> dict:
    """Machine-relative throughput metrics from a BENCH_mc.json tree.
    Missing sections simply drop out (only keys present in BOTH the
    committed baseline and the fresh run are compared, so adding benches
    never breaks CI)."""
    out = {}
    if "speedup_vs_loop" in record:
        out["layer_engine_speedup_vs_loop"] = record["speedup_vs_loop"]
    det = record.get("detector", {})
    if "speedup_vs_loop" in det:
        out["detector_engine_speedup_vs_loop"] = det["speedup_vs_loop"]
    step_us = record.get("qat", {}).get("step_us", {})
    if "1" in step_us and "4" in step_us:
        # chips=4 step cost relative to the single-draw step: the ensemble
        # path's own overhead factor, independent of runner speed
        out["qat_step_4chip_scale"] = 1.0 / (step_us["4"] / step_us["1"])
    return out   # all higher-is-better


def main() -> None:
    from benchmarks import mc_bench

    if not mc_bench.BENCH_JSON.exists():
        print("# no committed BENCH_mc.json baseline; nothing to gate")
        return
    baseline = _metrics(json.loads(mc_bench.BENCH_JSON.read_text()))

    # fresh run (rewrites BENCH_mc.json in the workspace — baseline captured
    # above; CI never commits the rewrite)
    for bench in (mc_bench.mc_engine_bench, mc_bench.detector_mc_bench,
                  mc_bench.qat_step_bench):
        for name, us, derived in bench():
            print(f"{name},{us:.1f},{derived}", flush=True)
    fresh = _metrics(json.loads(mc_bench.BENCH_JSON.read_text()))

    failures = []
    for name in sorted(baseline.keys() & fresh.keys()):
        ratio = baseline[name] / fresh[name]
        verdict = "FAIL" if ratio > DRIFT_FACTOR else "ok"
        print(f"# drift {name}: baseline={baseline[name]:.2f} "
              f"fresh={fresh[name]:.2f} regression={ratio:.2f}x [{verdict}]")
        if ratio > DRIFT_FACTOR:
            failures.append(name)
    for name in sorted(baseline.keys() - fresh.keys()):
        print(f"# drift {name}: skipped (absent from fresh run)")
    if failures:
        print(f"# benchmark drift >{DRIFT_FACTOR}x on: {', '.join(failures)}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
