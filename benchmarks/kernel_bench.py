"""Kernel benchmarks: the fused Pallas irc_mvm vs the pure-jnp structural
sim, and the packed ternary matmul vs a dense f32 matmul.

On this CPU container the Pallas kernels execute in INTERPRET mode, so
wall-clock numbers characterize the oracle/simulation cost, not TPU kernel
speed — the TPU-relevant artifact is the HLO op count (fusion) and the VMEM
tiling, reported as `derived`.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import (IrcEpilogueParams, irc_mvm, irc_mvm_ref,
                           ternary_matmul, ternary_matmul_ref)

Row = Tuple[str, float, str]


def _timeit(fn, n=3) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def _inputs(B, R, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    gp = (jax.random.uniform(ks[0], (R, N)) < 0.2).astype(jnp.float32)
    gn = ((jax.random.uniform(ks[1], (R, N)) < 0.2).astype(jnp.float32)
          * (1 - gp))
    ep = gp * jnp.exp(0.4245 * jax.random.normal(ks[2], (R, N))) + (1-gp)*1e-4
    en = gn * jnp.exp(0.4245 * jax.random.normal(ks[3], (R, N))) + (1-gn)*1e-4
    x = (jax.random.uniform(ks[4], (B, R)) < 0.5).astype(jnp.float32)
    eps = jax.random.normal(ks[5], (B, N))
    rnd = jax.random.bernoulli(ks[6], 0.5, (B, N)).astype(jnp.float32)
    return x, ep, en, gp, gn, eps, rnd


def irc_mvm_bench() -> List[Row]:
    rows: List[Row] = []
    params = IrcEpilogueParams()
    for B, R, N in ((32, 1024, 128), (64, 1024, 512)):
        args = _inputs(B, R, N)
        us_ref = _timeit(lambda: irc_mvm_ref(*args, params), n=2)
        us_kern = _timeit(lambda: irc_mvm(*args, params), n=2)
        match = float(jnp.mean(irc_mvm(*args, params)
                               == irc_mvm_ref(*args, params)))
        # HLO op count of the unfused jnp composition (TPU fusion argument)
        hlo = jax.jit(lambda *a: irc_mvm_ref(*a, params)).lower(*args
                                                                ).as_text()
        n_ops = sum(1 for l in hlo.splitlines() if " = " in l)
        rows.append((f"irc_mvm_{B}x{R}x{N}_ref_jnp", us_ref,
                     f"hlo_ops={n_ops}"))
        rows.append((f"irc_mvm_{B}x{R}x{N}_pallas(interp)", us_kern,
                     f"bitmatch={match:.4f};1_hbm_roundtrip"))
    return rows


def ternary_matmul_bench() -> List[Row]:
    rows: List[Row] = []
    B, K, N = 256, 2048, 512
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    w8 = jax.random.randint(k1, (K, N), -1, 2, dtype=jnp.int8)
    x = jax.random.normal(k2, (B, K))
    wf = w8.astype(jnp.float32)
    us_dense = _timeit(lambda: x @ wf)
    us_kern = _timeit(lambda: ternary_matmul(x, w8), n=2)
    err = float(jnp.max(jnp.abs(ternary_matmul(x, w8)
                                - ternary_matmul_ref(x, w8))))
    rows.append((f"ternary_dense_f32_{B}x{K}x{N}", us_dense,
                 f"hbm_weights={K*N*4/1e6:.1f}MB"))
    rows.append((f"ternary_packed_int8_{B}x{K}x{N}(interp)", us_kern,
                 f"err={err:.1e};hbm_weights={K*N/1e6:.1f}MB(4x_less)"))
    return rows


ALL = [irc_mvm_bench, ternary_matmul_bench]
